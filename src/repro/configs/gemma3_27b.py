"""gemma3-27b [hf:google/gemma-3]: 62L d=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global attention, 1024-token sliding window."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_head=128, d_ff=21504, vocab=262144, act="gelu", gated=True,
    local_window=1024, local_per_global=5,
)

REDUCED = TransformerConfig(
    name="gemma3-27b-smoke", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, act="gelu", gated=True,
    local_window=16, local_per_global=5, q_block=16,
)

SPEC = ArchSpec(
    name="gemma3-27b", family="lm", full=FULL, reduced=REDUCED,
    cells=lm_cells(full_attention=False),
    notes="5:1 local:global; local layers keep a window-sized rolling KV, "
          "so long_500k decode state is sub-quadratic and the cell runs",
)
