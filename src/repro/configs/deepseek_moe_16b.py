"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d=2048 16H (GQA kv=16)
d_ff=1408 vocab=102400; MoE 2 shared + 64 routed top-6, fine-grained."""
from ..models.transformer import MoEConfig, TransformerConfig
from .base import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=16, d_head=128, d_ff=1408, vocab=102400, act="silu",
    gated=True,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
)

REDUCED = TransformerConfig(
    name="deepseek-moe-16b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=96, vocab=256, act="silu", gated=True,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, d_expert=96),
    q_block=32,
)

SPEC = ArchSpec(
    name="deepseek-moe-16b", family="lm", full=FULL, reduced=REDUCED,
    cells=lm_cells(full_attention=True),
    notes="fine-grained MoE; experts sharded over the model axis (EP), "
          "tokens replicated across model + psum combine",
)
