"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse fields, embed_dim=16,
3 cross layers, MLP 1024-1024-512."""
from ..models.recsys import DCNConfig
from .base import ArchSpec, RECSYS_CELLS

FULL = DCNConfig(n_dense=13, n_sparse=26, vocab=1_000_000, embed_dim=16,
                 n_cross=3, mlp_dims=(1024, 1024, 512))
REDUCED = DCNConfig(n_dense=13, n_sparse=26, vocab=1000, embed_dim=8,
                    n_cross=2, mlp_dims=(64, 32))

SPEC = ArchSpec(
    name="dcn-v2", family="recsys", full=FULL, reduced=REDUCED,
    cells=dict(RECSYS_CELLS),
    notes="EmbeddingBag = take + segment-masked sum; tables row-sharded "
          "over the model axis",
)
