"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable eps."""
from ..models.gnn import GINConfig
from .base import ArchSpec, GNN_CELLS

FULL = GINConfig(n_layers=5, d_hidden=64)
REDUCED = GINConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=3)

SPEC = ArchSpec(
    name="gin-tu", family="gnn", full=FULL, reduced=REDUCED,
    cells=dict(GNN_CELLS),
    notes="SpMM regime (sum aggregation via segment_sum)",
)
