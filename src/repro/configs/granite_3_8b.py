"""granite-3-8b [hf:ibm-granite/granite-3.0]: 40L d=4096 32H (GQA kv=8)
d_ff=12800 vocab=49155."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_head=128, d_ff=12800, vocab=49155, act="silu", gated=True,
)

REDUCED = TransformerConfig(
    name="granite-3-8b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256, act="silu", gated=True,
    q_block=32,
)

SPEC = ArchSpec(
    name="granite-3-8b", family="lm", full=FULL, reduced=REDUCED,
    cells=lm_cells(full_attention=True),
    notes="dense GQA baseline of the LM family",
)
