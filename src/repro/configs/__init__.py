"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from .base import ArchSpec

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "dbrx-132b": "dbrx_132b",
    "gemma3-27b": "gemma3_27b",
    "nemotron-4-15b": "nemotron_4_15b",
    "granite-3-8b": "granite_3_8b",
    "gin-tu": "gin_tu",
    "nequip": "nequip",
    "meshgraphnet": "meshgraphnet",
    "egnn": "egnn",
    "dcn-v2": "dcn_v2",
    "ebbkc": "ebbkc",
}

ASSIGNED = [k for k in _MODULES if k != "ebbkc"]


def get(name: str) -> ArchSpec:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SPEC


def all_specs() -> Dict[str, ArchSpec]:
    return {name: get(name) for name in _MODULES}
