"""meshgraphnet [arXiv:2010.03409]: 15 MP layers, d_hidden=128,
sum aggregator, 2-layer MLPs."""
from ..models.gnn import MGNConfig
from .base import ArchSpec, GNN_CELLS

FULL = MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2, d_edge_in=4)
REDUCED = MGNConfig(n_layers=3, d_hidden=32, mlp_layers=2, d_node_in=8,
                    d_edge_in=4, d_out=3)

SPEC = ArchSpec(
    name="meshgraphnet", family="gnn", full=FULL, reduced=REDUCED,
    cells=dict(GNN_CELLS),
    notes="edge-featured MPNN; residual encode-process-decode",
)
