"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n)-equivariant."""
from ..models.gnn import EGNNConfig
from .base import ArchSpec, GNN_CELLS

FULL = EGNNConfig(n_layers=4, d_hidden=64)
REDUCED = EGNNConfig(n_layers=2, d_hidden=16, d_in=8, d_out=1)

SPEC = ArchSpec(
    name="egnn", family="gnn", full=FULL, reduced=REDUCED,
    cells=dict(GNN_CELLS),
    notes="cheap equivariant: scalar-distance messages + coordinate updates",
)
