"""nequip [arXiv:2101.03164]: 5 layers, mult=32, l_max=2, n_rbf=8,
cutoff=5, E(3)-equivariant tensor products."""
from ..models.equivariant import NequIPConfig
from .base import ArchSpec, GNN_CELLS

FULL = NequIPConfig(n_layers=5, mult=32, l_max=2, n_rbf=8, cutoff=5.0,
                    n_species=16)
REDUCED = NequIPConfig(n_layers=2, mult=8, l_max=2, n_rbf=4, cutoff=2.5,
                       n_species=4)

SPEC = ArchSpec(
    name="nequip", family="gnn", full=FULL, reduced=REDUCED,
    cells=dict(GNN_CELLS),
    notes="irrep tensor-product regime; real-Gaunt CG paths, features are "
          "positions + species (the modality frontend of citation-graph "
          "shapes is a stub per the assignment)",
)
