"""dbrx-132b [hf:databricks/dbrx-base]: 40L d=6144 48H (GQA kv=8)
d_ff=10752 vocab=100352; MoE 16 experts top-4."""
from ..models.transformer import MoEConfig, TransformerConfig
from .base import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_head=128, d_ff=10752, vocab=100352, act="silu", gated=True,
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, d_expert=10752),
)

REDUCED = TransformerConfig(
    name="dbrx-132b-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_head=8, d_ff=128, vocab=256, act="silu", gated=True,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128),
    q_block=32,
)

SPEC = ArchSpec(
    name="dbrx-132b", family="lm", full=FULL, reduced=REDUCED,
    cells=lm_cells(full_attention=True),
    notes="coarse MoE with large experts; top-4 of 16",
)
