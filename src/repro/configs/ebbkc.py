"""The paper's own arch: the distributed EBBkC clique engine.

Cells lower ``count_packed`` (plex routing + kernels) over sharded tile
batches -- the EdgeParallel scheme of paper Section 6.2(7) on the
production mesh.  These cells are *extra* (beyond the assigned 40)."""
import dataclasses

from .base import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class CliqueEngineConfig:
    tile_T: int = 64
    l: int = 3
    method: str = "mxu"


FULL = CliqueEngineConfig()
REDUCED = CliqueEngineConfig(tile_T=32, l=3, method="mxu")

CELLS = {
    "ep_tri_1m": ShapeCell("ep_tri_1m", "clique",
                           dims=dict(n_tiles=1048576, T=64, l=3)),
    "ep_tri_128": ShapeCell("ep_tri_128", "clique",
                            dims=dict(n_tiles=262144, T=128, l=3)),
    "ep_l4_ref": ShapeCell("ep_l4_ref", "clique",
                           dims=dict(n_tiles=65536, T=64, l=4)),
}

SPEC = ArchSpec(
    name="ebbkc", family="clique", full=FULL, reduced=REDUCED, cells=CELLS,
    notes="tiles sharded over every mesh axis (EP); per-device partial "
          "counts psum-reduced",
)
