"""Architecture config schema: every assigned arch is an ArchSpec with its
full (paper-exact) model config, a reduced smoke config, and its own
input-shape cells."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input shape) dry-run cell."""
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip: Optional[str] = None   # reason string if this cell is skipped


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                  # lm | gnn | recsys | clique
    full: Any                    # full model config (paper-exact numbers)
    reduced: Any                 # tiny config for CPU smoke tests
    cells: Dict[str, ShapeCell]
    notes: str = ""


LM_CELLS = {
    "train_4k": ShapeCell("train_4k", "train",
                          dims=dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeCell("prefill_32k", "prefill",
                             dims=dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeCell("decode_32k", "decode",
                            dims=dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeCell("long_500k", "decode",
                           dims=dict(seq_len=524288, global_batch=1)),
}


def lm_cells(full_attention: bool) -> Dict[str, ShapeCell]:
    cells = dict(LM_CELLS)
    if full_attention:
        cells["long_500k"] = dataclasses.replace(
            cells["long_500k"],
            skip="pure full-attention arch: 500k decode state is linear "
                 "full-KV with no sub-quadratic path; skipped per "
                 "assignment (DESIGN.md section 4)")
    return cells


GNN_CELLS = {
    "full_graph_sm": ShapeCell(
        "full_graph_sm", "train",
        dims=dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    "minibatch_lg": ShapeCell(
        "minibatch_lg", "train",
        dims=dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                  fanout0=15, fanout1=10, d_feat=602, n_classes=41)),
    "ogb_products": ShapeCell(
        "ogb_products", "train",
        dims=dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                  n_classes=47)),
    "molecule": ShapeCell(
        "molecule", "train",
        dims=dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
}

RECSYS_CELLS = {
    "train_batch": ShapeCell("train_batch", "train",
                             dims=dict(batch=65536)),
    "serve_p99": ShapeCell("serve_p99", "serve", dims=dict(batch=512)),
    "serve_bulk": ShapeCell("serve_bulk", "serve", dims=dict(batch=262144)),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                dims=dict(batch=1, n_candidates=1000000)),
}
