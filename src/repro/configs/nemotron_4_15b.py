"""nemotron-4-15b [arXiv:2402.16819]: 32L d=6144 48H (GQA kv=8) d_ff=24576
vocab=256000; squared-ReLU non-gated FFN."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="nemotron-4-15b", n_layers=32, d_model=6144, n_heads=48,
    n_kv_heads=8, d_head=128, d_ff=24576, vocab=256000, act="squared_relu",
    gated=False,
)

REDUCED = TransformerConfig(
    name="nemotron-4-15b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_head=8, d_ff=256, vocab=512, act="squared_relu",
    gated=False, q_block=32,
)

SPEC = ArchSpec(
    name="nemotron-4-15b", family="lm", full=FULL, reduced=REDUCED,
    cells=lm_cells(full_attention=True),
    notes="dense, squared-ReLU (Primer) activation",
)
