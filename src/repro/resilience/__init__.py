"""Fault injection, retry/fallback, and graceful degradation.

DESIGN.md section 12.  Three pieces:

* :mod:`repro.resilience.inject` -- deterministic, seeded fault injection
  with named sites threaded through the stack (off by default, no-op
  fast path like ``obs.trace``).
* :mod:`repro.resilience.retry` -- retry/backoff policies and the
  backend demotion ladder used by ``runtime.dispatch``.
* Typed failure exceptions re-exported here for callers.
"""

from .inject import ENV_FAULT_PLAN, SITES, FaultInjected, FaultPlan
from .inject import configure as configure_faults
from .inject import enabled as faults_enabled
from .retry import DEFAULT_POLICY, RetryPolicy, backoff_delay, demote

__all__ = [
    "ENV_FAULT_PLAN",
    "SITES",
    "FaultInjected",
    "FaultPlan",
    "configure_faults",
    "faults_enabled",
    "DEFAULT_POLICY",
    "RetryPolicy",
    "backoff_delay",
    "demote",
]
