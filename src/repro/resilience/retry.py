"""Retry/backoff policies and the backend demotion ladder.

Used by ``runtime.dispatch``: a failed tile batch is retried with capped
exponential backoff + deterministic jitter, then demoted down the backend
ladder (pallas -> lax -> ref -> host recursion).  Because EBBkC tiles are
independently recomputable (Eq. 2 exact-once attribution), every rung of
the ladder reproduces the lost batch exactly -- retries re-enter the same
FIFO/sequencer position, so results stay byte-identical to a fault-free
run (see DESIGN.md section 12).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple, Type

from . import inject


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``max_attempts`` bounds total tries (first call included); delays
    grow as ``base_delay_s * 2**(attempt-1)`` capped at ``max_delay_s``,
    scaled down by up to ``jitter`` using the same seeded hash stream as
    the fault injector, so chaos runs reproduce their timing decisions.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.0005
    max_delay_s: float = 0.02
    jitter: float = 0.5
    seed: int = 0


#: Policy for device-batch launches: a couple of quick retries, then the
#: caller demotes down the backend ladder.
DEFAULT_POLICY = RetryPolicy()

#: Policy for pure host stages (pack, decode, sink writes): the work has
#: no side effects until it succeeds, so the only cost of another attempt
#: is a tiny sleep -- retry hard enough that injected-fault schedules at
#: chaos rates (<= 0.5) never spuriously exhaust it (0.5**24 ~ 6e-8),
#: while a rate-1.0 site still surfaces after bounded work.
CONSUME_POLICY = RetryPolicy(max_attempts=24, base_delay_s=1e-4,
                             max_delay_s=2e-3)


def backoff_delay(policy: RetryPolicy, attempt: int, token: str = "") -> float:
    """Delay in seconds before retry ``attempt`` (1-based), jittered.

    The jitter draw is a pure function of (policy.seed, token, attempt),
    so two runs with the same failure pattern sleep identically.
    """
    base = min(policy.max_delay_s,
               policy.base_delay_s * (2.0 ** max(0, attempt - 1)))
    u = inject._u01(policy.seed, f"backoff:{token}", attempt)
    return base * (1.0 - policy.jitter * u)


def call(
    fn: Callable,
    *,
    policy: RetryPolicy = DEFAULT_POLICY,
    retry_on: Tuple[Type[BaseException], ...] = (inject.FaultInjected,),
    token: str = "",
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
):
    """Invoke ``fn()`` under the policy; re-raise once attempts exhaust.

    ``on_retry(attempt, exc)`` is called before each re-attempt (the
    dispatchers hook per-batch attempt accounting here).  Exceptions not
    in ``retry_on`` propagate immediately.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as exc:
            attempt += 1
            if attempt >= policy.max_attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            delay = backoff_delay(policy, attempt, token)
            if delay > 0:
                time.sleep(delay)


def consume(
    site: str,
    policy: RetryPolicy = CONSUME_POLICY,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> None:
    """Fire an injection site, absorbing injected faults by retrying.

    The hook for pure host stages (pack, decode, sink write): the stage
    body runs only after the site stops firing, so an injected fault
    costs a bounded number of scheduled draws and a few microseconds of
    backoff -- never a lost result.  A rate-1.0 site still exhausts the
    policy and raises (pathological plans stay observable).
    """
    if not inject.enabled():
        return
    call(lambda: inject.fire(site), policy=policy, token=site,
         on_retry=on_retry)


#: Backend ladders, best rung first.  ``ref`` implements counting only,
#: so the listing ladder ends at the host recursion (rung ``None``).
COUNT_LADDER = ("pallas", "lax", "ref")
LIST_LADDER = ("pallas", "lax")


def demote(mode: str, backend: Optional[str]) -> Optional[str]:
    """Next rung below ``backend`` for ``mode`` ('count' or 'list').

    Returns ``None`` when the ladder is exhausted -- the caller then
    falls back to the host recursion (exact partials for counting, the
    kernel-order host triple for listing).
    """
    ladder = COUNT_LADDER if mode == "count" else LIST_LADDER
    try:
        i = ladder.index(backend)
    except ValueError:
        return None
    return ladder[i + 1] if i + 1 < len(ladder) else None
