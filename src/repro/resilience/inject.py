"""Deterministic, seeded fault injection with named sites.

The chaos-engineering seam of the stack: every stage that can fail in
production -- artifact loads, extraction, packing, device staging, kernel
launches, harvests, decodes, sink writes -- calls :func:`fire` with its
site name.  With no plan configured that call is a single module-global
flag check (the same no-op discipline as ``obs.trace``, budget-tested in
``tests/test_resilience.py``).  With a plan, each site raises, delays, or
corrupts on a schedule that is a pure function of ``(seed, site, call#)``,
so a fault pattern reproduces exactly across runs with the same call
sequence.

Plan grammar (env ``REPRO_FAULT_PLAN`` or CLI ``--fault-plan``)::

    seed=7;*=0.1;kernel.launch=0.25;device.stage=0.1:delay:0.002

Semicolon-separated clauses.  ``seed=<int>`` seeds the schedule; every
other clause is ``<site>=<rate>[:<kind>[:<param>]]`` where ``site`` is one
of :data:`SITES` (or ``*`` as a default for all of them), ``rate`` is the
per-call firing probability in [0, 1], and ``kind`` is one of:

* ``raise`` (default) -- raise :class:`FaultInjected` at the site,
* ``delay`` -- sleep ``param`` seconds (default 0.001) and continue,
* ``corrupt`` -- flip bytes in the artifact being read; only artifact
  sites consult this via :func:`corrupt_bytes` (``plan.load``,
  ``tune.read``), elsewhere the clause is inert.

Injected-fault counts are tracked per site (:func:`fired`) and published
to the ``obs.metrics`` registry as ``repro_faults_injected_total{site=}``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from typing import Dict, Optional, Tuple, Union

#: Named fault sites, in stack order (plan load through sink write).
SITES = (
    "plan.load",
    "extract",
    "pack",
    "device.stage",
    "kernel.launch",
    "device.harvest",
    "decode",
    "sink.write",
    "tune.read",
)

#: Environment variable read at import time (the CLI ``--fault-plan``
#: flag sets it so worker threads and subprocesses agree).
ENV_FAULT_PLAN = "REPRO_FAULT_PLAN"

_KINDS = ("raise", "delay", "corrupt")


class FaultInjected(RuntimeError):
    """An injected fault (never raised by real failures).

    Carries the ``site`` and the 0-based ``call`` index at which the
    schedule fired, so logs identify the exact scheduled event.
    """

    def __init__(self, site: str, call: int):
        super().__init__(f"injected fault at site {site!r} (call #{call})")
        self.site = site
        self.call = call


@dataclasses.dataclass(frozen=True)
class SiteRule:
    """Per-site firing rule: probability, fault kind, kind parameter."""

    rate: float
    kind: str = "raise"
    param: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A parsed fault plan: per-site rules plus the schedule seed."""

    rules: Dict[str, SiteRule]
    seed: int = 0

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``seed=7;*=0.1;site=rate[:kind[:param]]`` grammar."""
        seed = 0
        rules: Dict[str, SiteRule] = {}
        default: Optional[SiteRule] = None
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            key, eq, val = clause.partition("=")
            if not eq:
                raise ValueError(f"bad fault-plan clause {clause!r} "
                                 f"(expected key=value)")
            key = key.strip()
            if key == "seed":
                seed = int(val)
                continue
            parts = val.split(":")
            rate = float(parts[0])
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"fault rate out of [0, 1]: {clause!r}")
            kind = parts[1].strip() if len(parts) > 1 and parts[1].strip() \
                else "raise"
            if kind not in _KINDS:
                raise ValueError(f"unknown fault kind {kind!r} "
                                 f"(one of {_KINDS})")
            param = float(parts[2]) if len(parts) > 2 else 0.0
            rule = SiteRule(rate, kind, param)
            if key == "*":
                default = rule
            elif key in SITES:
                rules[key] = rule
            else:
                raise ValueError(f"unknown fault site {key!r} "
                                 f"(sites: {', '.join(SITES)})")
        if default is not None:
            for site in SITES:
                rules.setdefault(site, default)
        return cls(rules, seed)


# module state: _ENABLED is the single-flag fast path checked by fire()
_ENABLED = False
_PLAN: Optional[FaultPlan] = None
_LOCK = threading.Lock()
_CALLS: Dict[str, int] = {}
_FIRED: Dict[str, int] = {}


def enabled() -> bool:
    """True when a fault plan is active."""
    return _ENABLED


def configure(plan: Union[None, str, FaultPlan]) -> Optional[FaultPlan]:
    """Install (or with ``None`` clear) the process-wide fault plan.

    Accepts a spec string (parsed with :meth:`FaultPlan.parse`) or a
    prebuilt plan; resets the per-site call/fired counters.  Returns the
    active plan.
    """
    global _ENABLED, _PLAN
    if plan is None:
        _ENABLED = False
        _PLAN = None
        reset_counts()
        return None
    if isinstance(plan, str):
        plan = FaultPlan.parse(plan)
    _PLAN = plan
    reset_counts()
    _ENABLED = True
    return plan


def reset_counts() -> None:
    """Zero the per-site call and fired counters (new schedule epoch)."""
    with _LOCK:
        _CALLS.clear()
        _FIRED.clear()


def calls(site: Optional[str] = None):
    """Per-site call counts (all sites as a dict when ``site`` is None)."""
    with _LOCK:
        if site is not None:
            return _CALLS.get(site, 0)
        return dict(_CALLS)


def fired(site: Optional[str] = None):
    """Per-site injected-fault counts (dict of all sites when None)."""
    with _LOCK:
        if site is not None:
            return _FIRED.get(site, 0)
        return dict(_FIRED)


def _u01(seed: int, site: str, call: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, site, call#)."""
    h = hashlib.blake2b(f"{seed}:{site}:{call}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0**64


def _draw(site: str, kind: str) -> Optional[Tuple[SiteRule, int]]:
    """Advance the site's schedule one call; return (rule, call#) when it
    fires for a rule of the given kind class ('fire' or 'corrupt')."""
    plan = _PLAN
    if plan is None:
        return None
    rule = plan.rules.get(site)
    if rule is None or rule.rate <= 0.0:
        return None
    wants_corrupt = rule.kind == "corrupt"
    if wants_corrupt != (kind == "corrupt"):
        return None
    with _LOCK:
        n = _CALLS.get(site, 0)
        _CALLS[site] = n + 1
    if _u01(plan.seed, site, n) >= rule.rate:
        return None
    with _LOCK:
        _FIRED[site] = _FIRED.get(site, 0) + 1
    _publish(site)
    return rule, n


def _publish(site: str) -> None:
    """Count one injected fault in the obs.metrics registry."""
    try:
        from ..obs import metrics as obs_metrics

        obs_metrics.get_registry().counter(
            "repro_faults_injected_total",
            help="faults injected by repro.resilience.inject",
            site=site,
        ).inc()
    except Exception:  # metrics must never break injection
        pass


def fire(site: str) -> None:
    """Fault-injection hook: no-op unless a plan schedules this call.

    The disabled path is a single global-flag check (overhead budget
    shared with ``obs.trace``).  ``raise`` rules raise
    :class:`FaultInjected`; ``delay`` rules sleep; ``corrupt`` rules are
    inert here (they act through :func:`corrupt_bytes`).
    """
    if not _ENABLED:
        return
    hit = _draw(site, "fire")
    if hit is None:
        return
    rule, n = hit
    if rule.kind == "delay":
        time.sleep(rule.param if rule.param > 0 else 0.001)
        return
    raise FaultInjected(site, n)


def corrupt_bytes(site: str, data: bytes) -> bytes:
    """Apply a scheduled ``corrupt`` rule to an artifact's raw bytes.

    Flips a deterministic byte (and truncates when ``param`` rounds to 1)
    so downstream integrity checks must catch it; a no-op unless the
    site's rule has ``kind=corrupt`` and the schedule fires this call.
    """
    if not _ENABLED:
        return data
    hit = _draw(site, "corrupt")
    if hit is None or not data:
        return data
    rule, n = hit
    if int(rule.param) == 1:  # param 1 = truncate instead of bit-flip
        return data[: len(data) // 2]
    pos = int(_u01(_PLAN.seed, site + "#pos", n) * len(data))
    mutated = bytearray(data)
    mutated[pos] ^= 0xFF
    return bytes(mutated)


# honor the environment at import time so every entry point (CLI, tests,
# worker threads) sees one consistent plan
_spec = os.environ.get(ENV_FAULT_PLAN)
if _spec:
    configure(_spec)
del _spec
