"""Unified streaming tile pipeline: CSR -> tau-bounded tiles -> packed batches.

This is the shared front-end for every EBBkC consumer (DESIGN.md section 2).
The paper's top-level edge branching produces one tau-bounded tile per edge
(Lemma 4.1); producing those tiles is the only data-dependent part of the
whole dataflow, so it must be vectorized end to end:

1. **Membership table** (:func:`TileTable` builders): one bulk ragged CSR
   expansion enumerates, for every edge at once, the common neighbors that
   survive the ordering filter (pi_tau rank for truss/hybrid, color-DAG
   position for color mode).  The table is *k-independent*: a query for any
   k only thresholds tile sizes (and color rules), so a
   :class:`PipelinePlan` amortizes all preprocessing across repeated
   queries on the same graph (the serving scenario).
2. **Capacity-based streaming batcher** (:func:`stream_batches`): tiles are
   routed to power-of-two size bins and packed ``batch_size`` at a time
   into fixed-shape ``(B, T, W)`` uint32 bitset batches -- host memory is
   bounded by one in-flight chunk per bin instead of the whole graph.
   Packing is vectorized: pairwise adjacency via one ``searchsorted`` over
   canonical edge keys, bit packing via ``np.packbits`` straight into the
   uint32 word layout the kernels consume.  Tiles wider than the largest
   bin are yielded as plain :class:`~repro.core.tiles.Tile` objects so the
   engine can spill them to the host recursion instead of aborting.
3. **Scheduler metadata**: every :class:`TileBatch` carries per-tile
   ``sizes``/``nedges`` arrays -- exactly the cost model inputs
   :func:`repro.runtime.clique_scheduler.schedule_tiles` consumes, so
   device bins map one-to-one onto packed batches.

The pure-Python extractor in :mod:`repro.core.tiles` is kept as the
reference oracle; parity tests assert byte-identical packed batches.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import hashlib
import itertools
import os
import threading
import time
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Tuple, \
    Union

import numpy as np

from .bitops import pack_bits as _pack_bits
from .graph import Graph, greedy_coloring, color_vertex_order, ragged_expand
from .tiles import Tile
from .truss import TrussDecomposition, truss_decomposition
from ..obs import trace
from ..resilience import inject
from ..resilience import retry as fault_retry

#: power-of-two tile-size bins; tiles wider than the last bin spill to host
BINS = (32, 64, 128, 256)


def _edge_lookup(ekeys: np.ndarray, m: int, n: int, lo: np.ndarray,
                 hi: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Membership probe for canonical pairs (lo < hi) against the sorted
    edge keys ``u * n + v``.

    Returns (hit mask, position in the sorted key array) -- position is
    only meaningful where ``hit``; callers needing the edge id (e.g. for a
    pi_tau rank lookup) index with it.  This is the single home of the
    searchsorted/clip/equality idiom; keep the key encoding in sync with
    :meth:`repro.core.graph.Graph.edge_keys`.
    """
    keys = lo * np.int64(n) + hi
    p = np.searchsorted(ekeys, keys)
    p = np.clip(p, 0, max(m - 1, 0))
    hit = (ekeys[p] == keys) if m else np.zeros(0, dtype=bool)
    return hit, p


def _group_offsets(E: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Segment boundaries of a value-sorted owner array.

    Returns (offsets (nt+1,), first index of each segment) -- the ragged
    tile layout shared by both membership-table builders.
    """
    if E.size:
        starts = np.concatenate(
            [[0], np.nonzero(np.diff(E) != 0)[0] + 1]).astype(np.int64)
        offsets = np.concatenate([starts, [E.size]]).astype(np.int64)
        return offsets, starts
    return np.zeros(1, dtype=np.int64), np.zeros(0, dtype=np.int64)


# ---------------------------------------------------------------------------
# k-independent membership tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TileTable:
    """Per-edge candidate-tile membership under one ordering family.

    ``family`` is "truss" (shared by truss and hybrid modes: members are the
    common neighbors reachable via edges ranked after e in pi_tau) or
    "color" (members are common out-neighbors in the color DAG).  Everything
    here is independent of k; :meth:`select` applies the k-dependent
    filters.
    """
    family: str
    edge_id: np.ndarray           # (nt,) source edge id per candidate tile
    anchors: np.ndarray           # (nt, 2) anchor vertices (S of Eq. 2)
    offsets: np.ndarray           # (nt+1,) ragged offsets into ``verts``
    verts: np.ndarray             # flat member vertices, canonical inner order
    thresh: np.ndarray            # (nt,) truss: rank(e); color: 0
    ekeys: np.ndarray             # sorted canonical edge keys (adjacency test)
    erank: Optional[np.ndarray]   # truss: pi_tau rank per edge id
    member_colors: Optional[np.ndarray] = None  # color: flat member colors
    ncolors: Optional[np.ndarray] = None        # color: distinct per tile
    rule1: Optional[np.ndarray] = None          # color: (nt,2) endpoint colors

    @property
    def ntiles(self) -> int:
        """Number of tiles in the table."""
        return int(self.edge_id.shape[0])

    def sizes(self) -> np.ndarray:
        """Per-tile candidate counts (``offsets`` diffs)."""
        return np.diff(self.offsets)

    def select(self, k: int, use_rule2: bool = True) -> np.ndarray:
        """Candidate tile ids surviving the k filters, canonical order."""
        keep = self.sizes() >= max(k - 2, 1)
        if self.family == "color":
            keep &= (self.rule1[:, 0] >= k) & (self.rule1[:, 1] >= k - 1)
            if use_rule2:
                keep &= self.ncolors >= k - 2
        return np.nonzero(keep)[0]


def _build_truss_table(g: Graph, td: TrussDecomposition,
                       eids: Optional[np.ndarray] = None) -> TileTable:
    """Truss-family membership table; ``eids`` restricts to a sorted
    subset of owner edges (the localized rebuild :mod:`repro.delta`
    splices into a repaired plan -- cost bounded by those edges'
    neighborhoods instead of m)."""
    ek = g.edge_keys()
    m = g.m
    sub = np.arange(m, dtype=np.int64) if eids is None \
        else np.asarray(eids, dtype=np.int64)
    if m == 0 or sub.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return TileTable("truss", z, np.zeros((0, 2), np.int64),
                         np.zeros(1, np.int64), z, z, ek, td.rank)
    deg = np.diff(g.indptr)
    u, v = g.edges[sub, 0], g.edges[sub, 1]
    swap = deg[u] > deg[v]
    a = np.where(swap, v, u)
    b = np.where(swap, u, v)
    r_e = td.rank
    owner, pos = ragged_expand(deg[a])
    idx = g.indptr[a][owner] + pos
    w = g.indices[idx]
    own_e = sub[owner]
    # pi_tau rank of the CSR edge (a, w) at each expanded slot: one bulk
    # 2m-key probe when building the whole table, per-slot probes (cost
    # bounded by the subset's neighborhoods) for a localized rebuild
    if eids is None:
        src = np.repeat(np.arange(g.n, dtype=np.int64), deg)
        rank_aw = td.rank[g.edge_ids(src, g.indices)][idx]
    else:
        rank_aw = r_e[g.edge_ids(a[owner], w)]
    keep = (rank_aw > r_e[own_e]) & (w != b[owner])
    own_e, w, bb = own_e[keep], w[keep], b[owner][keep]
    hit, p = _edge_lookup(ek, m, g.n, np.minimum(bb, w), np.maximum(bb, w))
    hit &= r_e[p] > r_e[own_e]
    E, W = own_e[hit], w[hit]
    # canonical order: reverse pi_tau over tiles, ascending vertex id inside
    order = np.lexsort((W, -r_e[E]))
    E, W = E[order], W[order]
    offsets, starts = _group_offsets(E)
    tile_edge = E[starts]
    return TileTable("truss", tile_edge, g.edges[tile_edge],
                     offsets, W, r_e[tile_edge], ek, td.rank)


def _build_color_table(g: Graph, colors: np.ndarray) -> TileTable:
    ek = g.edge_keys()
    m = g.m
    if m == 0:
        z = np.zeros(0, dtype=np.int64)
        return TileTable("color", z, np.zeros((0, 2), np.int64),
                         np.zeros(1, np.int64), z, z, ek, None,
                         member_colors=z, ncolors=z,
                         rule1=np.zeros((0, 2), np.int64))
    vorder = color_vertex_order(colors)
    vid = np.empty(g.n, dtype=np.int64)
    vid[vorder] = np.arange(g.n)
    u0, v0 = g.edges[:, 0], g.edges[:, 1]
    swapc = vid[u0] > vid[v0]
    ulo = np.where(swapc, v0, u0)
    vhi = np.where(swapc, u0, v0)
    deg = np.diff(g.indptr)
    a = np.where(deg[ulo] <= deg[vhi], ulo, vhi)
    b = np.where(deg[ulo] <= deg[vhi], vhi, ulo)
    owner, pos = ragged_expand(deg[a])
    idx = g.indptr[a][owner] + pos
    w = g.indices[idx]
    # member iff vid[w] beyond both endpoints (DAG out-neighbor of each)
    keep = (vid[w] > vid[vhi][owner]) & (w != b[owner])
    owner, w = owner[keep], w[keep]
    bb = b[owner]
    hit, _ = _edge_lookup(ek, m, g.n, np.minimum(bb, w), np.maximum(bb, w))
    E, W = owner[hit], w[hit]
    # canonical order: edge id ascending, members by color-DAG position
    order = np.lexsort((vid[W], E))
    E, W = E[order], W[order]
    offsets, starts = _group_offsets(E)
    tile_edge = E[starts]
    mcol = colors[W]
    nt = tile_edge.size
    sizes = np.diff(offsets)
    tid_rep, _ = ragged_expand(sizes)
    if E.size:
        o2 = np.lexsort((mcol, tid_rep))
        c2, t2 = mcol[o2], tid_rep[o2]
        new = np.concatenate([[True], (t2[1:] != t2[:-1]) |
                              (c2[1:] != c2[:-1])])
        ncolors = np.bincount(t2[new], minlength=nt)
    else:
        ncolors = np.zeros(0, dtype=np.int64)
    rule1 = np.stack([colors[ulo[tile_edge]], colors[vhi[tile_edge]]], axis=1)
    return TileTable("color", tile_edge,
                     np.stack([ulo[tile_edge], vhi[tile_edge]], axis=1),
                     offsets, W, np.zeros(nt, dtype=np.int64), ek, None,
                     member_colors=mcol, ncolors=ncolors, rule1=rule1)


# ---------------------------------------------------------------------------
# PipelinePlan: cached preprocessing for repeated queries on one graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelinePlan:
    """Per-graph preprocessing cache (truss order, coloring, tables).

    Build once, query many times: ``stream_batches(plan, k)`` for any k
    reuses the decomposition and the membership table, so a serving process
    pays preprocessing once per graph snapshot.
    """
    g: Graph
    _td: Optional[TrussDecomposition] = None
    _colors: Optional[np.ndarray] = None
    _tables: Dict[str, TileTable] = dataclasses.field(default_factory=dict)

    @property
    def td(self) -> TrussDecomposition:
        """The graph's truss decomposition (computed lazily, cached)."""
        if self._td is None:
            self._td = truss_decomposition(self.g)
        return self._td

    @property
    def colors(self) -> np.ndarray:
        """Greedy vertex coloring (computed lazily, cached)."""
        if self._colors is None:
            self._colors, _ = greedy_coloring(self.g)
        return self._colors

    def table(self, mode: str) -> TileTable:
        """The (lazily built, cached) tile table for ``mode``'s family."""
        family = "color" if mode == "color" else "truss"
        if family not in self._tables:
            if family == "truss":
                self._tables[family] = _build_truss_table(self.g, self.td)
            else:
                self._tables[family] = _build_color_table(self.g, self.colors)
        return self._tables[family]


def build_plan(g: Graph, order: str = "hybrid") -> PipelinePlan:
    """Eagerly preprocess ``g`` for ``order`` (truss/hybrid/color)."""
    if order not in ("truss", "hybrid", "color"):
        raise ValueError(f"unknown edge-tile mode: {order}")
    plan = PipelinePlan(g=g)
    plan.table(order)
    return plan


def _as_plan(source: Union[Graph, PipelinePlan]) -> PipelinePlan:
    return source if isinstance(source, PipelinePlan) else PipelinePlan(source)


# ---------------------------------------------------------------------------
# plan persistence + keyed in-process cache (DESIGN.md section 8)
# ---------------------------------------------------------------------------

#: serialized-plan layout version; bump on any TileTable schema change so a
#: stale on-disk plan is rebuilt instead of misread
PLAN_FORMAT = 1

#: in-process plan cache capacity (plans, LRU-evicted); a plan holds the
#: graph plus O(sum tile sizes) table arrays, so keep the window small
PLAN_CACHE_CAPACITY = 8

#: canonicalization contract baked into every plan key: two graphs share
#: a key only when their *canonical* forms (self-loops dropped, edges
#: dedup'd and lexsorted u < v) match under the same contract version --
#: a future change to ``graph.from_edges`` canonicalization must bump
#: this tag so stale plans re-key instead of aliasing
PLAN_CANON = "dedup-lexsorted-v1"

_PLAN_CACHE: "collections.OrderedDict[str, PipelinePlan]" = \
    collections.OrderedDict()
_PLAN_CACHE_LOCK = threading.Lock()
# per-key single-flight build latches (cached_plan): key -> Event set
# when the winning builder has published (or abandoned) its plan
_PLAN_BUILDS: Dict[str, threading.Event] = {}


def plan_key(g: Graph, order: str = "hybrid") -> str:
    """Content-addressed cache key over the *whole* graph identity.

    Hashes the vertex count, edge count, canonicalization contract
    (:data:`PLAN_CANON`), ordering family, and the canonical edge list.
    ``n`` matters even with identical edges: edge keys are ``u * n + v``,
    so a plan built for a smaller vertex set mis-probes adjacency on a
    graph with trailing isolated vertices (the aliasing regression in
    ``test_pipeline.py``).  Truss and hybrid modes share one key (both
    consume the "truss" membership table); color mode keys separately.
    O(m) to compute -- negligible next to the O(delta*m) decomposition it
    lets a warm query skip.
    """
    family = "color" if order == "color" else "truss"
    h = hashlib.sha256()
    h.update(
        f"plan-v{PLAN_FORMAT}:{PLAN_CANON}:{family}:{g.n}:{g.m}:".encode())
    h.update(np.ascontiguousarray(g.edges).tobytes())
    return h.hexdigest()[:24]


def save_plan(plan: PipelinePlan, directory: str,
              lineage: Optional[Dict] = None) -> str:
    """Persist a plan's built structures via :mod:`repro.checkpoint.store`.

    Saves the graph plus whatever is already built (truss decomposition,
    coloring, membership tables) -- load never recomputes what was saved.
    Atomic like every checkpoint (tmp dir + os.replace + COMMITTED).
    ``lineage`` is an optional JSON dict recording how the plan came to be
    (graph version, parent plan key, repair-vs-rebuild decision -- written
    by :class:`repro.delta.PlanIndex`); it rides in the metadata and is
    readable without deserializing arrays via
    :func:`repro.checkpoint.store.read_metadata`.
    """
    from ..checkpoint import store

    tree: Dict[str, object] = {
        "graph": {"n": np.asarray(plan.g.n, np.int64),
                  "edges": plan.g.edges, "indptr": plan.g.indptr,
                  "indices": plan.g.indices}}
    if plan._td is not None:
        td = plan._td
        tree["truss_dec"] = {
            "order": td.order, "rank": td.rank, "support0": td.support0,
            "peel_support": td.peel_support, "trussness": td.trussness,
            "tau": np.asarray(td.tau, np.int64)}
    if plan._colors is not None:
        tree["colors"] = plan._colors
    tables: Dict[str, Dict[str, np.ndarray]] = {}
    for family, tb in plan._tables.items():
        d = {"edge_id": tb.edge_id, "anchors": tb.anchors,
             "offsets": tb.offsets, "verts": tb.verts,
             "thresh": tb.thresh, "ekeys": tb.ekeys}
        for opt in ("erank", "member_colors", "ncolors", "rule1"):
            val = getattr(tb, opt)
            if val is not None:
                d[opt] = val
        tables[family] = d
    if tables:
        tree["tables"] = tables
    metadata: Dict[str, object] = {
        "format": PLAN_FORMAT, "families": sorted(plan._tables)}
    if lineage is not None:
        metadata["lineage"] = lineage
    return store.save_checkpoint(directory, 0, tree, metadata=metadata)


def load_plan(directory: str) -> Optional[PipelinePlan]:
    """Restore a :func:`save_plan` plan; None if absent/stale-format.

    Corrupt or truncated stores (failed length+digest check, unreadable
    npz/meta, or a tree that no longer parses) also read as absent: the
    bad step is quarantined -- moved aside under ``<dir>/quarantine/``
    with a warning log -- so the caller rebuilds and re-saves instead of
    propagating a deserialization traceback (the same fall-back-to-absent
    contract as ``tune.records``).
    """
    from ..checkpoint import store

    got = store.restore_checkpoint_safe(directory, _corrupt_site="plan.load")
    if got is None or got["metadata"].get("format") != PLAN_FORMAT:
        return None
    try:
        flat = got["tree"]
        g = Graph(n=int(flat["graph/n"]), edges=flat["graph/edges"],
                  indptr=flat["graph/indptr"], indices=flat["graph/indices"])
        plan = PipelinePlan(g=g)
        if "truss_dec/rank" in flat:
            plan._td = TrussDecomposition(
                order=flat["truss_dec/order"], rank=flat["truss_dec/rank"],
                support0=flat["truss_dec/support0"],
                peel_support=flat["truss_dec/peel_support"],
                trussness=flat["truss_dec/trussness"],
                tau=int(flat["truss_dec/tau"]))
        if "colors" in flat:
            plan._colors = flat["colors"]
        for family in got["metadata"].get("families", []):
            p = f"tables/{family}/"
            plan._tables[family] = TileTable(
                family, flat[p + "edge_id"], flat[p + "anchors"],
                flat[p + "offsets"], flat[p + "verts"], flat[p + "thresh"],
                flat[p + "ekeys"], flat.get(p + "erank"),
                member_colors=flat.get(p + "member_colors"),
                ncolors=flat.get(p + "ncolors"), rule1=flat.get(p + "rule1"))
    except Exception as exc:
        store.quarantine(directory, reason=f"plan parse failed: {exc!r}")
        return None
    return plan


def _plan_cache_insert(key: str, plan: PipelinePlan) -> None:
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE[key] = plan
        _PLAN_CACHE.move_to_end(key)
        while len(_PLAN_CACHE) > PLAN_CACHE_CAPACITY:
            _PLAN_CACHE.popitem(last=False)


def clear_plan_cache() -> None:
    """Drop every in-process cached plan (tests / memory pressure)."""
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE.clear()


def cached_plan(g: Graph, order: str = "hybrid", *,
                cache_dir: Optional[str] = None,
                stats=None) -> PipelinePlan:
    """Plan for ``g``/``order`` off the keyed cache; build only on miss.

    Lookup order: in-process LRU (keyed by :func:`plan_key`) -> on-disk
    plan store under ``cache_dir`` (persisted across processes via
    :func:`save_plan`) -> build (and save when ``cache_dir`` is given).
    A warm hit skips the O(delta*m) truss/coloring preprocessing entirely;
    ``stats`` (a :class:`~repro.core.engine_np.Stats`) records
    ``plan_cache_hit`` and the cold-path ``plan_build_s``.

    Thread-safe with per-key single-flight building: concurrent misses on
    one key elect exactly one builder; the losers block on its latch and
    then take the published plan as a cache hit (``plan_cache_hit=True``,
    no ``plan_build_s``), so the O(delta*m) build runs once no matter how
    many threads race a cold key.  If the builder dies, a blocked loser
    takes over.  Plans themselves are read-only after their table is
    built.
    """
    if order not in ("truss", "hybrid", "color"):
        raise ValueError(f"unknown edge-tile mode: {order}")
    key = plan_key(g, order)
    family = "color" if order == "color" else "truss"
    while True:
        latch = None
        with _PLAN_CACHE_LOCK:
            plan = _PLAN_CACHE.get(key)
            if plan is not None and family in plan._tables:
                _PLAN_CACHE.move_to_end(key)
            else:
                plan = None
                latch = _PLAN_BUILDS.get(key)
                if latch is None:
                    # no builder in flight: this thread becomes it
                    _PLAN_BUILDS[key] = threading.Event()
        if plan is not None:
            if stats is not None:
                stats.plan_cache_hit = True
            trace.instant("plan/cache_hit", source="memory", order=order)
            return plan
        if latch is None:
            break
        # single-flight: another thread owns the build; wait for its
        # latch, then loop to take the published plan as a hit (or, if
        # the builder failed without publishing, become the builder)
        with trace.span("plan/build_wait", order=order):
            latch.wait()
    try:
        if cache_dir is not None:
            with trace.span("plan/load", order=order):
                try:
                    inject.fire("plan.load")
                    plan = load_plan(os.path.join(cache_dir, key))
                except inject.FaultInjected:
                    plan = None  # injected load fault -> a cache miss
            if plan is not None and family in plan._tables:
                if stats is not None:
                    stats.plan_cache_hit = True
                trace.instant("plan/cache_hit", source="disk", order=order)
                _plan_cache_insert(key, plan)
                return plan
        t0 = time.perf_counter()
        with trace.span("plan/build", order=order, n=g.n, m=g.m):
            plan = build_plan(g, order=order)
        if stats is not None:
            stats.plan_build_s += time.perf_counter() - t0
        if cache_dir is not None:
            save_plan(plan, os.path.join(cache_dir, key))
        _plan_cache_insert(key, plan)
        return plan
    finally:
        with _PLAN_CACHE_LOCK:
            latch = _PLAN_BUILDS.pop(key, None)
        if latch is not None:
            latch.set()


# ---------------------------------------------------------------------------
# vectorized chunk packing
# ---------------------------------------------------------------------------

# pairwise-expansion budget per internal slice (caps peak index memory)
_PAIR_BUDGET = 4_000_000


def _chunk_dense(g: Graph, table: TileTable, ids: np.ndarray, T: int):
    """Dense bool adjacency for one chunk of candidate tiles.

    Returns (D (B,T,T) bool, V (B,T) padded member ids, sizes, nedges,
    pairs) with ``pairs = (tile, i, j, pair_rank)`` for i<j adjacent pairs
    (pair_rank is the pi_tau rank of the pair edge for the truss family).
    """
    ids = np.asarray(ids, dtype=np.int64)
    B = ids.size
    sz = (table.offsets[ids + 1] - table.offsets[ids]).astype(np.int64)
    owner, pos = ragged_expand(sz)
    V = np.zeros((B, T), dtype=np.int64)
    V[owner, pos] = table.verts[table.offsets[ids][owner] + pos]
    D = np.zeros((B, T, T), dtype=bool)
    po_l: List[np.ndarray] = []
    pi_l: List[np.ndarray] = []
    pj_l: List[np.ndarray] = []
    pr_l: List[np.ndarray] = []
    # slice the chunk so the i x j pair expansion stays within budget
    start = 0
    quad = sz.astype(np.int64) ** 2
    cum = np.cumsum(quad)
    while start < B:
        stop = int(np.searchsorted(
            cum, (cum[start - 1] if start else 0) + _PAIR_BUDGET) + 1)
        stop = max(start + 1, min(stop, B))
        sl = slice(start, stop)
        so = sz[sl]
        powner, ppos = ragged_expand(so * so)
        s_rep = so[powner]
        i = ppos // s_rep
        j = ppos % s_rep
        keep = i < j
        powner, i, j = powner[keep], i[keep], j[keep]
        powner_g = powner + start
        gu = V[powner_g, i]
        gv = V[powner_g, j]
        hit, p = _edge_lookup(table.ekeys, g.m, g.n,
                              np.minimum(gu, gv), np.maximum(gu, gv))
        if table.family == "truss":
            hit &= table.erank[p] > table.thresh[ids[powner_g]]
        powner_g, i, j, p = powner_g[hit], i[hit], j[hit], p[hit]
        D[powner_g, i, j] = True
        D[powner_g, j, i] = True
        po_l.append(powner_g)
        pi_l.append(i)
        pj_l.append(j)
        if table.family == "truss":
            pr_l.append(table.erank[p])
        start = stop
    po = np.concatenate(po_l) if po_l else np.zeros(0, np.int64)
    pi = np.concatenate(pi_l) if pi_l else np.zeros(0, np.int64)
    pj = np.concatenate(pj_l) if pj_l else np.zeros(0, np.int64)
    pr = (np.concatenate(pr_l) if pr_l else np.zeros(0, np.int64)) \
        if table.family == "truss" else None
    nedges = np.bincount(po, minlength=B).astype(np.int64)
    return D, V, sz, nedges, (po, pi, pj, pr)


def _greedy_color_chunk(D: np.ndarray, sz: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized-across-tiles greedy coloring, replicating ``_local_color``.

    Processing order per tile: degree descending, local id descending (the
    reference's ``sorted(..., reverse=True)`` tie-break); color = smallest
    positive value unused by any tile-neighbor.  Returns (colors (B,T) with
    0 on padding, perm (B,T) = relabel order: color desc, id asc, padding
    last).
    """
    B, T, _ = D.shape
    ids = np.broadcast_to(np.arange(T, dtype=np.int64), (B, T))
    deg = D.sum(-1).astype(np.int64)
    real = ids < sz[:, None]
    degk = np.where(real, deg, -1)
    order = np.lexsort((-ids, -degk), axis=1)
    colors = np.zeros((B, T), dtype=np.int64)
    for t in range(int(sz.max(initial=0))):
        # step t touches only tiles with a t-th vertex; indexing the active
        # subset keeps per-step work O(#active * T), not O(B * T) -- the
        # dominant win on mixed-size bins (bench_pipeline_stages)
        act = np.nonzero(t < sz)[0]
        v = order[act, t]
        nb = D[act, v]                                    # (A, T)
        ncol = np.where(nb, colors[act], 0)
        present = np.zeros((act.size, T + 2), dtype=bool)
        present[np.arange(act.size)[:, None], ncol] = True
        mex = np.argmin(present[:, 1:], axis=1) + 1       # first free >= 1
        colors[act, v] = mex
    perm = np.lexsort((ids, -colors), axis=1)
    return colors, perm


def _relabel_chunk(D, V, colors, perm):
    # one flat gather for the (B, T, T) permute (measurably faster than
    # both a chained take_along_axis and the triple-broadcast fancy index)
    B, T = V.shape
    idx = (perm[:, :, None] * T + perm[:, None, :]).reshape(B, T * T)
    D2 = np.take_along_axis(D.reshape(B, T * T), idx, axis=1) \
        .reshape(B, T, T)
    V2 = np.take_along_axis(V, perm, axis=1)
    C2 = np.take_along_axis(colors, perm, axis=1)
    return D2, V2, C2


@dataclasses.dataclass
class TileBatch:
    """One fixed-shape packed batch plus per-tile scheduler metadata.

    ``verts`` is the decode table of the emission subsystem
    (:mod:`repro.core.listing`): local slot i of tile b is global vertex
    ``verts[b, i]`` (post-relabel for hybrid mode; slots >= ``sizes[b]``
    are padding).  Together with ``anchors`` it is everything needed to
    translate kernel-emitted local clique ids back to global ids.
    """
    T: int
    A: np.ndarray        # (B, T, W) uint32 adjacency bitsets
    cand: np.ndarray     # (B, W) uint32 candidate masks
    sizes: np.ndarray    # (B,) int32 member counts
    nedges: np.ndarray   # (B,) int32 tile edge counts (cost-model input)
    anchors: np.ndarray  # (B, 2) int64 anchor vertices
    verts: np.ndarray    # (B, T) int64 local slot -> global vertex id

    @property
    def B(self) -> int:
        """Batch size: number of packed tiles (rows) in this batch."""
        return int(self.A.shape[0])


def _pack_batch(g: Graph, table: TileTable, ids: np.ndarray, T: int,
                mode: str) -> TileBatch:
    # pure function of (table, ids): an injected pack fault is absorbed
    # by in-place retry before any work happens, so results never change
    fault_retry.consume("pack")
    D, V, sz, nedges, _ = _chunk_dense(g, table, ids, T)
    if mode == "hybrid":
        colors, perm = _greedy_color_chunk(D, sz)
        D, V, _ = _relabel_chunk(D, V, colors, perm)
    A = _pack_bits(D)
    cand = _pack_bits(np.arange(T)[None, :] < sz[:, None])
    return TileBatch(T, A, cand, sz.astype(np.int32),
                     nedges.astype(np.int32), table.anchors[ids].copy(), V)


def _tiles_from_ids(g: Graph, table: TileTable, ids: np.ndarray,
                    mode: str) -> Iterator[Tile]:
    """Materialize reference-identical :class:`Tile` objects for ``ids``."""
    ids = np.asarray(ids, dtype=np.int64)
    chunk = 512
    for c0 in range(0, ids.size, chunk):
        sub = ids[c0:c0 + chunk]
        sz = (table.offsets[sub + 1] - table.offsets[sub]).astype(np.int64)
        T = max(8, int(-(-int(sz.max(initial=1)) // 8) * 8))
        D, V, _, nedges, (po, pi, pj, pr) = _chunk_dense(g, table, sub, T)
        colors_out: Optional[np.ndarray] = None
        if mode == "hybrid":
            colors, perm = _greedy_color_chunk(D, sz)
            D, V, colors_out = _relabel_chunk(D, V, colors, perm)
        elif mode == "color":
            mowner, mpos = ragged_expand(sz)
            colors_out = np.zeros((sub.size, T), dtype=np.int64)
            colors_out[mowner, mpos] = table.member_colors[
                table.offsets[sub][mowner] + mpos]
        edges_ranked: Optional[List[List[Tuple[int, int]]]] = None
        if mode == "truss":
            o = np.lexsort((pr, po))
            po_s, pi_s, pj_s = po[o], pi[o], pj[o]
            bounds = np.concatenate(
                [[0], np.cumsum(np.bincount(po_s, minlength=sub.size))])
            edges_ranked = [
                list(zip(pi_s[bounds[b]:bounds[b + 1]].tolist(),
                         pj_s[bounds[b]:bounds[b + 1]].tolist()))
                for b in range(sub.size)]
        row_bytes = np.packbits(D, axis=-1, bitorder="little")
        for b in range(sub.size):
            s = int(sz[b])
            rows = [int.from_bytes(row_bytes[b, r].tobytes(), "little")
                    for r in range(s)]
            anchor = (int(table.anchors[sub[b], 0]),
                      int(table.anchors[sub[b], 1]))
            verts = V[b, :s].copy()
            if mode == "truss":
                yield Tile(anchor, verts, rows, int(nedges[b]),
                           edges_ranked=edges_ranked[b])
            else:
                yield Tile(anchor, verts, rows, int(nedges[b]),
                           colors=[int(c) for c in colors_out[b, :s]])


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def iter_tiles(source: Union[Graph, PipelinePlan], k: int,
               mode: str = "hybrid", use_rule2: bool = True
               ) -> Iterator[Tile]:
    """Vectorized replacement for :func:`repro.core.tiles.edge_tiles`.

    Yields tiles identical (same order, members, rows, colors/ranks) to the
    Python reference extractor, built from the plan's membership table.
    """
    if mode not in ("truss", "hybrid", "color"):
        raise ValueError(f"unknown edge-tile mode: {mode}")
    plan = _as_plan(source)
    table = plan.table(mode)
    ids = table.select(k, use_rule2=use_rule2)
    yield from _tiles_from_ids(plan.g, table, ids, mode)


def default_pack_workers() -> int:
    """Auto worker count for the parallel pack producer: a small pool,
    leaving one core for the consumer/device side (packing is numpy-bound
    and releases the GIL, but past a few threads the front-end saturates
    host memory bandwidth -- and on CPU-device hosts the packers share
    cores with the kernels themselves)."""
    return max(1, min(4, (os.cpu_count() or 2) - 1))


def stream_batches(source: Union[Graph, PipelinePlan], k: int,
                   order: str = "hybrid", use_rule2: bool = True,
                   batch_size: Optional[int] = None,
                   bins: Optional[Sequence[int]] = None,
                   timings: Optional[Dict[str, float]] = None,
                   pack_workers: Optional[int] = 0,
                   prefetch: Optional[int] = None,
                   stats=None) -> Iterator[Union[TileBatch, Tile]]:
    """Stream fixed-shape packed batches (plus oversize spill tiles).

    Tiles are routed to the smallest bin T >= size and packed
    ``batch_size`` at a time, so peak host memory is one chunk per bin.
    Tiles wider than ``bins[-1]`` are yielded as :class:`Tile` objects for
    the caller to spill to the host recursion.  When ``timings`` is given,
    "extract" (table build + select) and "pack" seconds are accumulated
    into it.

    ``pack_workers`` turns the serial packer into a producer/consumer
    pipeline: a thread pool packs up to ``prefetch`` chunks ahead of the
    consumer (default ``2 * workers``), so host packing of batch i+N
    overlaps whatever the consumer does with batch i (device dispatch, in
    the engines).  ``0`` = pack inline (the serial reference behavior);
    ``None`` = :func:`default_pack_workers`.  The yielded sequence is
    **identical** in content and order either way -- work items are
    submitted and harvested strictly FIFO -- and peak host memory grows
    only by the prefetch window.  With ``stats`` given (a
    :class:`~repro.core.engine_np.Stats`), ``pack_workers``,
    ``frontend_s`` (extract + pack seconds; worker CPU-seconds when
    parallel), and the prefetch-queue occupancy fields are recorded.
    """
    if order not in ("truss", "hybrid", "color"):
        raise ValueError(f"unknown edge-tile mode: {order}")
    # None = the historical defaults; the engines resolve tuned geometry
    # (repro.tune.search.resolve_geometry) before calling in, so this
    # module stays tuner-agnostic
    if batch_size is None:
        batch_size = 256
    bins = tuple(sorted(int(b) for b in (BINS if bins is None else bins)))
    if any(b % 32 for b in bins):
        raise ValueError("bins must be multiples of 32")
    plan = _as_plan(source)
    t0 = time.perf_counter()
    with trace.span("extract", order=order, k=k) as _sp:
        fault_retry.consume("extract")  # pure stage: retry-in-place
        table = plan.table(order)
        ids = table.select(k, use_rule2=use_rule2)
        sizes = (table.offsets[ids + 1] - table.offsets[ids]).astype(np.int64)
        binidx = np.searchsorted(np.asarray(bins), sizes)
        _sp.set(tiles=int(ids.size))
    extract_s = time.perf_counter() - t0
    if timings is not None:
        timings["extract"] = timings.get("extract", 0.0) + extract_s
    if stats is not None:
        stats.frontend_s += extract_s
    for tid in ids[binidx == len(bins)]:
        yield from _tiles_from_ids(plan.g, table, np.asarray([tid]), order)

    def bill_pack(dt: float) -> None:
        if timings is not None:
            timings["pack"] = timings.get("pack", 0.0) + dt
        if stats is not None:
            stats.frontend_s += dt

    # the work list (bin, chunk) is cheap to materialize -- only index
    # arrays -- and fixes the deterministic yield order up front
    work: List[Tuple[int, np.ndarray]] = []
    for bi, T in enumerate(bins):
        sel = ids[binidx == bi]
        for c0 in range(0, sel.size, batch_size):
            work.append((T, sel[c0:c0 + batch_size]))
    workers = default_pack_workers() if pack_workers is None \
        else max(0, int(pack_workers))
    serial = workers == 0 or len(work) <= 1
    if stats is not None:
        # report what actually ran: the <=1-work-item fallback is serial
        stats.pack_workers = 0 if serial else workers
    if serial:
        for T, chunk in work:
            t1 = time.perf_counter()
            with trace.span("pack", T=T, tiles=len(chunk)):
                batch = _pack_batch(plan.g, table, chunk, T, order)
            bill_pack(time.perf_counter() - t1)
            yield batch
        return

    def pack_job(T: int, chunk: np.ndarray) -> Tuple[TileBatch, float]:
        t1 = time.perf_counter()
        with trace.span("pack", T=T, tiles=len(chunk)):
            batch = _pack_batch(plan.g, table, chunk, T, order)
        return batch, time.perf_counter() - t1

    depth = max(2, 2 * workers) if prefetch is None else max(1, int(prefetch))
    occ_sum, occ_n, occ_peak = 0.0, 0, 0
    ex = concurrent.futures.ThreadPoolExecutor(max_workers=workers)
    try:
        it = iter(work)
        futs: Deque = collections.deque(
            ex.submit(pack_job, T, chunk)
            for T, chunk in itertools.islice(it, depth))
        while futs:
            occ_peak = max(occ_peak, len(futs))
            occ_sum += len(futs) / depth
            occ_n += 1
            fut = futs.popleft()
            if fut.done():
                batch, dt = fut.result()
            else:
                with trace.span("pack/wait", depth=len(futs) + 1):
                    batch, dt = fut.result()
            nxt = next(it, None)
            if nxt is not None:
                futs.append(ex.submit(pack_job, *nxt))
            bill_pack(dt)
            yield batch
    finally:
        ex.shutdown(wait=False, cancel_futures=True)
        if stats is not None and occ_n:
            stats.pack_queue_occupancy = occ_sum / occ_n
            stats.pack_queue_peak = occ_peak
