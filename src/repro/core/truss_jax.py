"""On-device truss decomposition (vectorized parallel peeling).

The host peeler (:mod:`repro.core.truss`) removes one edge at a time --
exact pi_tau, O(delta*m), but serial.  This JAX variant peels *rounds*
(all min-support edges at once) with dense boolean adjacency: round-based
peeling yields the identical trussness values and tau (the per-round edge
sets are exactly the classic k-truss peeling levels), only the intra-level
order differs -- which the engine never relies on (attribution is by rank
filter, and any level-consistent order bounds tiles by tau).

Intended for fully-on-device pipelines over modest n (dense (n, n) bool
adjacency); the benchmark graphs and per-partition subgraphs qualify.
Support computation = triangle message passing (gather rows + AND + sum),
the same segment primitive the GNN substrate uses.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .graph import Graph


def truss_decomposition_jax(g: Graph) -> Tuple[np.ndarray, int]:
    """Returns (trussness per edge (m,), tau). Exact (tested vs host)."""
    n, m = g.n, g.m
    if m == 0:
        return np.zeros(0, np.int64), 0
    adj = jnp.zeros((n, n), jnp.bool_)
    e = jnp.asarray(g.edges, jnp.int32)
    adj = adj.at[e[:, 0], e[:, 1]].set(True)
    adj = adj.at[e[:, 1], e[:, 0]].set(True)

    def support(adj, alive):
        rows_u = adj[e[:, 0]]              # (m, n)
        rows_v = adj[e[:, 1]]
        s = jnp.sum(rows_u & rows_v, axis=1).astype(jnp.int32)
        return jnp.where(alive, s, jnp.int32(1 << 30))

    def cond(state):
        adj, alive, truss, level = state
        return alive.any()

    def body(state):
        adj, alive, truss, level = state
        sup = support(adj, alive)
        cur = jnp.min(sup)
        level = jnp.maximum(level, cur)
        frontier = alive & (sup <= level)
        truss = jnp.where(frontier, level, truss)
        adj = adj.at[e[:, 0], e[:, 1]].min(~frontier)
        adj = adj.at[e[:, 1], e[:, 0]].min(~frontier)
        return adj, alive & ~frontier, truss, level

    alive0 = jnp.ones((m,), jnp.bool_)
    truss0 = jnp.zeros((m,), jnp.int32)
    _, _, truss, level = jax.lax.while_loop(
        cond, body, (adj, alive0, truss0, jnp.int32(0)))
    return np.asarray(truss, np.int64), int(level)
