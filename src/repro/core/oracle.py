"""Brute-force oracles for correctness tests (small graphs only)."""
from __future__ import annotations

from itertools import combinations
from typing import List, Tuple


from .graph import Graph


def count_kcliques_brute(g: Graph, k: int) -> int:
    return len(list_kcliques_brute(g, k))


def list_kcliques_brute(g: Graph, k: int) -> List[Tuple[int, ...]]:
    if k == 1:
        return [(v,) for v in range(g.n)]
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    out = []
    for combo in combinations(range(g.n), k):
        ok = True
        for a, b in combinations(combo, 2):
            if b not in adj[a]:
                ok = False
                break
        if ok:
            out.append(combo)
    return out


def count_kcliques_nx(g: Graph, k: int) -> int:
    """networkx-based count (handles moderately larger graphs)."""
    import networkx as nx

    G = nx.Graph()
    G.add_nodes_from(range(g.n))
    G.add_edges_from(map(tuple, g.edges.tolist()))
    total = 0
    for c in nx.enumerate_all_cliques(G):
        if len(c) == k:
            total += 1
        elif len(c) > k:
            break
    return total
