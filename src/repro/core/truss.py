"""Truss decomposition and the truss-based edge ordering (paper Section 4.2).

The ordering pi_tau iteratively removes the edge whose endpoints have the
minimum number of common neighbors (the edge *support*), appending it to the
order.  This is exactly truss decomposition peeling; the max support observed
at removal time is tau = k_max - 2, and Lemma 4.1 proves tau < delta.

Host implementation: bucket-queue peeling, O(m * delta) like the paper's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .graph import Graph, ragged_expand


@dataclasses.dataclass(frozen=True)
class TrussDecomposition:
    order: np.ndarray      # (m,) edge ids in removal order (= pi_tau)
    rank: np.ndarray       # (m,) rank[e] = position of edge e in pi_tau
    support0: np.ndarray   # (m,) initial supports (triangles per edge)
    peel_support: np.ndarray  # (m,) support at removal time (<= tau)
    trussness: np.ndarray  # (m,) classic trussness t(e); k_max = max+2
    tau: int               # max peel support == k_max - 2


def edge_supports(g: Graph) -> np.ndarray:
    """Initial support (number of triangles containing each edge).

    Vectorized: one ragged CSR expansion of the lower-degree endpoint's
    neighborhood per edge, membership-tested against the sorted canonical
    edge keys with a single ``searchsorted``.
    """
    if g.m == 0:
        return np.zeros(0, dtype=np.int64)
    deg = np.diff(g.indptr)
    u, v = g.edges[:, 0], g.edges[:, 1]
    a = np.where(deg[u] <= deg[v], u, v)
    b = np.where(deg[u] <= deg[v], v, u)
    counts = deg[a]
    owner, pos = ragged_expand(counts)
    idx = g.indptr[a][owner] + pos
    w = g.indices[idx]
    hit = g.has_edges(b[owner], w)
    return np.bincount(owner[hit], minlength=g.m).astype(np.int64)


def edge_subset_supports(g: Graph, eids: np.ndarray) -> np.ndarray:
    """Support (triangle count) for just the edges ``eids`` of ``g``.

    The localized half of :func:`edge_supports`: cost is bounded by the
    neighborhoods of the requested edges, not m -- this is what lets
    :mod:`repro.delta` re-derive supports only for the edges an update
    batch touched.
    """
    eids = np.asarray(eids, dtype=np.int64)
    if eids.size == 0 or g.m == 0:
        return np.zeros(eids.size, dtype=np.int64)
    deg = np.diff(g.indptr)
    u, v = g.edges[eids, 0], g.edges[eids, 1]
    a = np.where(deg[u] <= deg[v], u, v)
    b = np.where(deg[u] <= deg[v], v, u)
    counts = deg[a]
    owner, pos = ragged_expand(counts)
    idx = g.indptr[a][owner] + pos
    w = g.indices[idx]
    hit = g.has_edges(b[owner], w) & (w != b[owner])
    return np.bincount(owner[hit], minlength=eids.size).astype(np.int64)


def truss_decomposition(g: Graph) -> TrussDecomposition:
    m = g.m
    if m == 0:
        z = np.zeros(0, dtype=np.int64)
        return TrussDecomposition(z, z, z, z, z, 0)
    sup0 = edge_supports(g)
    sup = sup0.copy()
    # mutable adjacency: vertex -> {neighbor: edge_id}
    adj: List[Dict[int, int]] = [dict() for _ in range(g.n)]
    for i in range(m):
        u, v = int(g.edges[i, 0]), int(g.edges[i, 1])
        adj[u][v] = i
        adj[v][u] = i
    maxsup = int(sup.max())
    bucket: List[List[int]] = [[] for _ in range(maxsup + 1)]
    for i in range(m):
        bucket[sup[i]].append(i)
    removed = np.zeros(m, dtype=bool)
    order = np.empty(m, dtype=np.int64)
    peel = np.empty(m, dtype=np.int64)
    trussness = np.empty(m, dtype=np.int64)
    cur = 0
    level = 0  # running max of min-support at removal -> tau
    cnt = 0
    while cnt < m:
        while cur <= maxsup and not bucket[cur]:
            cur += 1
        e = bucket[cur].pop()
        if removed[e] or sup[e] != cur:
            # stale entry (support changed since push)
            continue
        removed[e] = True
        level = max(level, cur)
        order[cnt] = e
        peel[cnt] = cur
        trussness[e] = level
        cnt += 1
        u, v = int(g.edges[e, 0]), int(g.edges[e, 1])
        del adj[u][v]
        del adj[v][u]
        a, b = (u, v) if len(adj[u]) <= len(adj[v]) else (v, u)
        bn = adj[b]
        for w, ea in list(adj[a].items()):
            eb = bn.get(w)
            if eb is None:
                continue
            for ee in (ea, eb):
                if not removed[ee]:
                    s = sup[ee] - 1
                    sup[ee] = s
                    bucket[s].append(ee)
                    if s < cur:
                        cur = s
    rank = np.empty(m, dtype=np.int64)
    rank[order] = np.arange(m)
    peel_by_edge = np.empty(m, dtype=np.int64)
    peel_by_edge[order] = peel
    return TrussDecomposition(order=order, rank=rank, support0=sup0,
                              peel_support=peel_by_edge,
                              trussness=trussness, tau=int(level))


def tau_delta_gap(g: Graph) -> Tuple[int, int]:
    """(tau, delta) pair; Lemma 4.1 asserts tau < delta on every graph."""
    from .graph import degeneracy_order
    td = truss_decomposition(g)
    _, delta = degeneracy_order(g)
    return td.tau, delta
