"""EBBkC public API: edge-oriented branch-and-bound k-clique listing.

``count`` / ``list_cliques`` run the paper's Algorithms 2-7 over the tile
dataflow of :mod:`repro.core.pipeline` (vectorized extraction; the Python
reference extractor lives in :mod:`repro.core.tiles`).  ``backend="host"``
executes the paper-faithful python-int bitset recursion; ``backend="jax"``
streams capacity-batched fixed-shape uint32 batches through the accelerator
engine (:mod:`repro.core.engine_jax`), which is what the multi-pod
deployment uses.  Pass a prebuilt :class:`~repro.core.pipeline.PipelinePlan`
as ``plan`` to amortize preprocessing across queries on one graph.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .engine_np import Stats, count_rec_C, count_rec_T, list_rec_C
from .graph import Graph
from . import pipeline


@dataclasses.dataclass
class Result:
    count: int
    stats: Stats
    tiles: int = 0
    max_tile: int = 0


def count(g: Graph, k: int, order: str = "hybrid", et_t: int = 3,
          use_rule2: bool = True, backend: str = "host",
          engine_kwargs: Optional[dict] = None,
          plan: Optional[pipeline.PipelinePlan] = None) -> Result:
    """Count k-cliques with edge-oriented branching (EBBkC-T/C/H)."""
    if k < 1:
        raise ValueError("k >= 1 required")
    stats = Stats()
    stats.backend = "host"
    if k == 1:
        return Result(g.n, stats)
    if k == 2:
        return Result(g.m, stats)
    if backend == "jax":
        from . import engine_jax
        return engine_jax.count(g, k, order=order, et_t=et_t,
                                use_rule2=use_rule2, plan=plan,
                                **(engine_kwargs or {}))
    total = 0
    ntiles = 0
    max_tile = 0
    l = k - 2
    for tile in pipeline.iter_tiles(plan or g, k, mode=order,
                                    use_rule2=use_rule2):
        ntiles += 1
        max_tile = max(max_tile, tile.s)
        cand = (1 << tile.s) - 1
        if order == "truss":
            total += count_rec_T(tile.edges_ranked, cand, tile.s, l, stats,
                                 et_t=et_t)
        else:
            total += count_rec_C(tile.rows, cand, l, stats,
                                 colors=tile.colors, et_t=et_t,
                                 use_rule2=use_rule2)
    return Result(total, stats, ntiles, max_tile)


def list_cliques(g: Graph, k: int, order: str = "hybrid", et_t: int = 3,
                 max_out: Optional[int] = None,
                 plan: Optional[pipeline.PipelinePlan] = None,
                 backend: str = "host",
                 engine_kwargs: Optional[dict] = None
                 ) -> Tuple[np.ndarray, Stats]:
    """List k-cliques; returns (count x k) array of global vertex ids.

    With ``max_out`` set, exactly ``min(max_out, total)`` cliques are
    returned (a whole tile's results are collected before the bound check,
    then truncated).  ``backend="jax"`` streams packed batches through the
    Pallas emission kernels (:mod:`repro.core.listing`) -- identical clique
    set, never truncated on emit-buffer overflow (overflowed tiles re-list
    on the host, ``stats.overflowed_tiles``); ``engine_kwargs`` forwards
    knobs like ``devices=`` / ``capacity=`` to ``listing.stream_cliques``.
    """
    stats = Stats()
    stats.backend = "host"
    if k == 1:
        out = np.arange(g.n, dtype=np.int64)[:, None]
        return out[:max_out], stats
    if k == 2:
        return g.edges[:max_out].copy(), stats
    if backend == "jax":
        from . import listing
        sink = listing.ArraySink(k, max_out=max_out)
        res = listing.stream_cliques(plan or g, k, sink, order=order,
                                     et_t=et_t, **(engine_kwargs or {}))
        return sink.result(), res.stats
    out_all: List[Tuple[int, ...]] = []
    for tile in pipeline.iter_tiles(plan or g, k, mode=order):
        cand = (1 << tile.s) - 1
        local: List[Tuple[int, ...]] = []
        list_rec_C(tile.rows, cand, k - 2, (), local, et_t=et_t)
        for tup in local:
            out_all.append(tile.anchor + tuple(int(tile.verts[i])
                                               for i in tup))
        if max_out is not None and len(out_all) >= max_out:
            arr = np.asarray(out_all[:max_out], dtype=np.int64).reshape(-1, k)
            return np.sort(arr, axis=1), stats
    if not out_all:
        return np.zeros((0, k), dtype=np.int64), stats
    arr = np.asarray(out_all, dtype=np.int64)
    return np.sort(arr, axis=1), stats
