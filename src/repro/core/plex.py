"""Early-termination on dense branches (paper Section 5).

A branch graph g that is a t-plex (every vertex has at most t non-neighbors
including itself) can be finished without further BB branching:

* t <= 2: closed-form / combinatorial (kC2Plex, Alg. 6).  The vertex set
  partitions into F (universal vertices) and a perfect matching of
  non-adjacent pairs L+R.  An l-clique takes any c vertices from F and any
  j = l-c vertices from the p pairs, at most one per pair:

      count(l) = sum_c C(|F|, c) * C(p, l-c) * 2^(l-c)

  TPU adaptation: the whole ET becomes branch-free arithmetic.

* t >= 3: kCtPlex (Alg. 7) branches on the sparse inverse graph.  The
  count-only TPU adaptation keeps its key ingredient -- factoring out the
  universal set I combinatorially -- and finishes the (small) non-universal
  remainder with the generic engine.
"""
from __future__ import annotations

from math import comb
from typing import Iterator, List, Sequence, Tuple

from .bitops import bits, mask_gt, popcount


def plexity(rows: Sequence[int], cand: int) -> Tuple[int, int]:
    """Return (nv, t) where the candidate-induced graph is a t-plex.

    t = nv - min_degree_within (counting the vertex itself as a non-neighbor).
    """
    nv = popcount(cand)
    if nv == 0:
        return 0, 0
    mind = min(popcount(rows[v] & cand) for v in bits(cand))
    return nv, nv - mind


def split_universal(rows: Sequence[int], cand: int) -> Tuple[int, int]:
    """(F, rest): F = vertices adjacent to all other cand vertices."""
    nv = popcount(cand)
    F = 0
    for v in bits(cand):
        if popcount(rows[v] & cand) == nv - 1:
            F |= 1 << v
    return F, cand & ~F


def count_2plex(f: int, p: int, l: int) -> int:
    """l-cliques in (f universal vertices) + (p disjoint non-adjacent pairs)."""
    total = 0
    for c in range(max(0, l - p), min(l, f) + 1):
        j = l - c
        total += comb(f, c) * comb(p, j) * (1 << j)
    return total


def count_in_2plex(rows: Sequence[int], cand: int, l: int) -> int:
    F, rest = split_universal(rows, cand)
    p, r = divmod(popcount(rest), 2)
    assert r == 0, "2-plex non-universal part must pair up"
    return count_2plex(popcount(F), p, l)


def match_pairs(rows: Sequence[int], rest: int) -> List[Tuple[int, int]]:
    """Pair each non-universal 2-plex vertex with its unique non-neighbor."""
    pairs = []
    seen = 0
    for v in bits(rest):
        if (seen >> v) & 1:
            continue
        non = rest & ~rows[v] & ~(1 << v)
        w = next(bits(non))
        pairs.append((v, w))
        seen |= (1 << v) | (1 << w)
    return pairs


def list_2plex(rows: Sequence[int], cand: int, l: int) -> Iterator[Tuple[int, ...]]:
    """kC2Plex (Alg. 6): enumerate l-cliques combinatorially.

    Yields tuples of local vertex ids.
    """
    from itertools import combinations

    F, rest = split_universal(rows, cand)
    Fl = list(bits(F))
    pairs = match_pairs(rows, rest)
    p = len(pairs)
    if len(Fl) + p < l:  # |F| + |L| < l -> nothing (Alg. 6 line 2)
        return
    for c1 in range(max(0, l - p), min(l, len(Fl)) + 1):
        for fsub in combinations(Fl, c1):
            j = l - c1
            for psub in combinations(range(p), j):
                # each chosen pair contributes one of its two endpoints
                for sel in range(1 << j):
                    out = list(fsub)
                    for t, pi in enumerate(psub):
                        out.append(pairs[pi][(sel >> t) & 1])
                    yield tuple(out)


def list_tplex(rows: Sequence[int], cand: int, l: int) -> Iterator[Tuple[int, ...]]:
    """kCtPlex (Alg. 7): branch on the inverse graph; I factored via combos."""
    from itertools import combinations

    nv = popcount(cand)
    inv = {v: cand & ~rows[v] & ~(1 << v) for v in bits(cand)}
    I = 0
    for v in bits(cand):
        if inv[v] == 0:
            I |= 1 << v
    Il = list(bits(I))
    C0 = cand & ~I

    def rec(S: Tuple[int, ...], C: int, lp: int) -> Iterator[Tuple[int, ...]]:
        if lp == 0:
            yield S
            return
        if len(Il) >= lp:
            for isub in combinations(Il, lp):
                yield S + isub
        # choose at least one vertex from C
        for v in bits(C):
            Ci = C & mask_gt(v) & ~inv[v]
            if popcount(Ci) + len(Il) >= lp - 1:
                yield from rec(S + (v,), Ci, lp - 1)

    yield from rec((), C0, l)
