"""Bit-manipulation helpers -- the single home for both engines.

Host engine uses arbitrary-precision python ints as bitsets (C-speed AND /
popcount via ``int.bit_count``), mirroring the paper's adjacency-bitmap
implementations (BitCol/SDegree).  The device engine uses packed uint32
words; the packed-word helpers here (``pack_bits``, ``gt_masks_np``, the
traced ``popcount_words`` / ``unpack_bits``) are shared by the vectorized
pipeline, the Pallas kernels (re-exported via ``repro.kernels.common``),
and tests -- one definition, one test (``tests/test_bitops.py``).
"""

from __future__ import annotations

import sys
from typing import Iterator, List, Sequence

import numpy as np

WORD = 32

_LITTLE = sys.byteorder == "little"


# ---------------------------------------------------------------------------
# python-int bitsets (host recursion)
# ---------------------------------------------------------------------------


def bits(x: int) -> Iterator[int]:
    """Iterate set bit positions of a python-int bitset (ascending)."""
    while x:
        lsb = x & -x
        yield lsb.bit_length() - 1
        x ^= lsb


def popcount(x: int) -> int:
    return x.bit_count()


def mask_lt(i: int) -> int:
    """Bits {0..i-1}."""
    return (1 << i) - 1


def mask_gt(i: int) -> int:
    """Bits {i+1, i+2, ...} up to a practical width handled by callers."""
    return -1 << (i + 1)  # python ints: arbitrarily wide; AND with cand clips


def rows_from_pairs(num_vertices: int, pairs: Sequence[tuple]) -> List[int]:
    rows = [0] * num_vertices
    for a, b in pairs:
        rows[a] |= 1 << b
        rows[b] |= 1 << a
    return rows


# ---------------------------------------------------------------------------
# packed uint32 words (device tiles)
# ---------------------------------------------------------------------------


def num_words(T: int) -> int:
    assert T % WORD == 0, "tile size must be a multiple of 32"
    return T // WORD


def pack_bits(dense: np.ndarray) -> np.ndarray:
    """(..., T) bool -> (..., T//32) uint32; bit j of word w = column 32w+j.

    Matches :func:`pack_rows` bit-for-bit but runs as one ``np.packbits``
    call instead of a per-bit Python loop.
    """
    packed = np.packbits(dense, axis=-1, bitorder="little")
    if not _LITTLE:  # pragma: no cover - big-endian hosts
        shape = packed.shape
        packed = packed.reshape(shape[:-1] + (-1, 4))[..., ::-1].reshape(shape)
    return np.ascontiguousarray(packed).view(np.uint32)


def gt_masks_np(T: int) -> np.ndarray:
    """(T, W) uint32: gt[v] has exactly the bits {v+1, ..., T-1} set."""
    dense = np.arange(T)[None, :] > np.arange(T)[:, None]
    return pack_bits(dense)


def pack_rows(rows: Sequence[int], T: int) -> np.ndarray:
    """python-int bitset rows -> (T, T//WORD) uint32 (pad with zeros)."""
    W = (T + WORD - 1) // WORD
    out = np.zeros((T, W), dtype=np.uint32)
    full = (1 << WORD) - 1
    for i, r in enumerate(rows):
        for w in range(W):
            out[i, w] = (r >> (w * WORD)) & full
    return out


def pack_mask(mask: int, T: int) -> np.ndarray:
    W = (T + WORD - 1) // WORD
    out = np.zeros((W,), dtype=np.uint32)
    full = (1 << WORD) - 1
    for w in range(W):
        out[w] = (mask >> (w * WORD)) & full
    return out


def unpack_mask(words: np.ndarray) -> int:
    x = 0
    for w, v in enumerate(np.asarray(words, dtype=np.uint64).tolist()):
        x |= int(v) << (w * WORD)
    return x


def dense_from_rows(rows: Sequence[int], T: int) -> np.ndarray:
    """(T, T) {0,1} uint8 adjacency from python-int rows."""
    out = np.zeros((T, T), dtype=np.uint8)
    for i, r in enumerate(rows):
        for j in bits(r):
            if j < T:
                out[i, j] = 1
    return out


# ---------------------------------------------------------------------------
# traced packed-word helpers (device kernels; re-exported by kernels.common).
# jax is imported lazily so the host engine chain (engine_np -> bitops)
# stays jax-free at import time; after the first call it's a dict lookup.
# ---------------------------------------------------------------------------


def popcount_words(x):
    """Per-word popcount of packed (..., W) uint32 (traced)."""
    import jax

    return jax.lax.population_count(x)


def unpack_bits(x, T: int):
    """(..., W) uint32 -> (..., T) {0,1} uint32 (bit j of word w -> w*32+j)."""
    import jax.numpy as jnp

    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    out = (x[..., None] >> shifts) & jnp.uint32(1)
    return out.reshape(*x.shape[:-1], T)


def bit_at(x, v):
    """Extract bit v (scalar, possibly traced) from packed (..., W) uint32."""
    import jax.numpy as jnp

    v = jnp.asarray(v, dtype=jnp.int32)
    word = jnp.take(x, v // WORD, axis=-1)
    return (word >> (v % WORD).astype(jnp.uint32)) & jnp.uint32(1)
