"""Bitset helpers.

Host engine uses arbitrary-precision python ints as bitsets (C-speed AND /
popcount via ``int.bit_count``), mirroring the paper's adjacency-bitmap
implementations (BitCol/SDegree).  The device engine uses packed uint32 words;
packing utilities here are shared by tests and the JAX path.
"""
from __future__ import annotations

from typing import Iterator, List, Sequence

import numpy as np

WORD = 32


def bits(x: int) -> Iterator[int]:
    """Iterate set bit positions of a python-int bitset (ascending)."""
    while x:
        lsb = x & -x
        yield lsb.bit_length() - 1
        x ^= lsb


def popcount(x: int) -> int:
    return x.bit_count()


def mask_lt(i: int) -> int:
    """Bits {0..i-1}."""
    return (1 << i) - 1


def mask_gt(i: int) -> int:
    """Bits {i+1, i+2, ...} up to a practical width handled by callers."""
    return -1 << (i + 1)  # python ints: arbitrarily wide; AND with cand clips


def rows_from_pairs(num_vertices: int, pairs: Sequence[tuple]) -> List[int]:
    rows = [0] * num_vertices
    for a, b in pairs:
        rows[a] |= 1 << b
        rows[b] |= 1 << a
    return rows


def pack_rows(rows: Sequence[int], T: int) -> np.ndarray:
    """python-int bitset rows -> (T, T//WORD) uint32 (pad with zeros)."""
    W = (T + WORD - 1) // WORD
    out = np.zeros((T, W), dtype=np.uint32)
    full = (1 << WORD) - 1
    for i, r in enumerate(rows):
        for w in range(W):
            out[i, w] = (r >> (w * WORD)) & full
    return out


def pack_mask(mask: int, T: int) -> np.ndarray:
    W = (T + WORD - 1) // WORD
    out = np.zeros((W,), dtype=np.uint32)
    full = (1 << WORD) - 1
    for w in range(W):
        out[w] = (mask >> (w * WORD)) & full
    return out


def unpack_mask(words: np.ndarray) -> int:
    x = 0
    for w, v in enumerate(np.asarray(words, dtype=np.uint64).tolist()):
        x |= int(v) << (w * WORD)
    return x


def dense_from_rows(rows: Sequence[int], T: int) -> np.ndarray:
    """(T, T) {0,1} uint8 adjacency from python-int rows."""
    out = np.zeros((T, T), dtype=np.uint8)
    for i, r in enumerate(rows):
        for j in bits(r):
            if j < T:
                out[i, j] = 1
    return out
