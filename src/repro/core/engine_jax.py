"""Accelerator counting engine: tiles -> packed bitset batches -> kernels.

Pipeline (the TPU-native EBBkC of DESIGN.md section 2):
  1. vectorized tile extraction + capacity-batched packing
     (:mod:`repro.core.pipeline`) under the chosen ordering -- fixed-shape
     (B, T, T/32) uint32 batches stream off the host with bounded memory
     (lockstep SPMD wants tight bins; the truss ordering makes them tight,
     Lemma 4.1);
  2. oversize routing: tiles wider than the largest bin spill to the host
     bitset recursion (counted in ``Stats.spilled_tiles``) instead of
     aborting the query;
  3. early-termination routing (Section 5, vectorized): per-tile plexity is a
     popcount reduction; t<=2 tiles are answered by the closed-form
     2-plex formula (exact int64 Pascal-table arithmetic, branch-free);
  4. everything else goes to the Pallas kernels: MXU matmul base case for
     l==3, bitset DFS for l>=4.

``count_packed`` is the jit-able inner step used by the distributed launcher
(`repro.launch.clique`): tile batches are sharded over the mesh data axes and
the per-device partial counts are psum-reduced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .engine_np import Stats, count_rec_C, count_rec_T
from .graph import Graph
from . import pipeline
from . import tiles as tiles_mod
from .bitops import pack_rows, pack_mask
from ..kernels import ops as kops
from ..kernels.common import pascal_table, popcount, unpack_bits
from ..tune import search as tune_search

_BINS = pipeline.BINS


def bucket_rows(x: np.ndarray) -> np.ndarray:
    """Zero-pad axis 0 up to the next power of two (batch-shape bucketing).

    Ragged tail chunks of a bin then reuse the pow2-batch executables the
    full chunks already compiled, instead of compiling one executable per
    distinct tail length.  Padding rows have ``cand == 0``, contributing
    exactly 0 to kernel counts, the closed-form 2-plex count, and the
    listing buffers (callers slice the padded rows off before decode).
    """
    B = x.shape[0]
    p = 1
    while p < B:
        p *= 2
    if p == B:
        return x
    return np.concatenate([x, np.zeros((p - B,) + x.shape[1:], x.dtype)])


@dataclasses.dataclass
class PackedTiles:
    """One fixed-shape batch of bitset tiles."""
    A: np.ndarray      # (B, T, W) uint32
    cand: np.ndarray   # (B, W) uint32


def pack_tiles(tiles: List[tiles_mod.Tile], T: int) -> PackedTiles:
    """Pack ``tiles`` into one fixed-shape ``(B, T, W)`` bitset batch."""
    B = len(tiles)
    W = T // 32
    A = np.zeros((B, T, W), dtype=np.uint32)
    cand = np.zeros((B, W), dtype=np.uint32)
    for i, t in enumerate(tiles):
        A[i] = pack_rows(t.rows, T)
        cand[i] = pack_mask((1 << t.s) - 1, T)
    return PackedTiles(A, cand)


def bin_tiles(g: Graph, k: int, order: str = "hybrid",
              use_rule2: bool = True,
              plan: Optional[pipeline.PipelinePlan] = None,
              spill: Optional[List[tiles_mod.Tile]] = None,
              bins: Sequence[int] = _BINS) -> Dict[int, PackedTiles]:
    """Extract edge tiles and pack them into size bins (materialized).

    Thin compatibility wrapper over :func:`repro.core.pipeline.stream_batches`
    that concatenates the streamed chunks per bin.  Oversize tiles are
    appended to ``spill`` when given, else raise (the pre-pipeline
    behavior); :func:`count` always spills.
    """
    parts: Dict[int, List[pipeline.TileBatch]] = {}
    for item in pipeline.stream_batches(plan or g, k, order=order,
                                        use_rule2=use_rule2, bins=bins):
        if isinstance(item, tiles_mod.Tile):
            if spill is None:
                raise ValueError(
                    f"tile with {item.s} vertices exceeds max bin "
                    f"{max(bins)}; raise bins or spill to host")
            spill.append(item)
            continue
        parts.setdefault(item.T, []).append(item)
    return {T: PackedTiles(np.concatenate([b.A for b in bs]),
                           np.concatenate([b.cand for b in bs]))
            for T, bs in sorted(parts.items())}


# ---------------------------------------------------------------------------
# vectorized early termination (closed-form 2-plex counting)
# ---------------------------------------------------------------------------

def plex_stats(A: jax.Array, cand: jax.Array) -> Tuple[jax.Array, ...]:
    """Per tile: (nv, t, f) = size, plexity, #universal vertices."""
    T = A.shape[1]
    vbit = unpack_bits(cand, T)                       # (B, T)
    deg = popcount(A & cand[:, None, :]).sum(-1)      # (B, T)
    nv = popcount(cand).sum(-1)                       # (B,)
    big = jnp.int32(1 << 30)
    deg_v = jnp.where(vbit > 0, deg.astype(jnp.int32), big)
    mind = jnp.min(deg_v, axis=-1)
    mind = jnp.where(nv > 0, mind, 0)
    t = nv.astype(jnp.int32) - mind
    f = jnp.sum((deg.astype(jnp.int32) == nv[:, None].astype(jnp.int32) - 1)
                & (vbit > 0), axis=-1)
    return nv.astype(jnp.int32), t, f


def count_2plex_closed_np(nv: np.ndarray, f: np.ndarray, l: int) -> np.ndarray:
    """Closed-form Section 5.1 count; exact int64 on host (cheap, O(B*l))."""
    table = pascal_table(int(max(nv.max(initial=0), 1)))
    p = (nv - f) // 2
    total = np.zeros(nv.shape, dtype=np.int64)
    for c in range(0, l + 1):
        j = l - c
        cf = np.where(c <= f, table[f, np.minimum(c, f)], 0)
        cp = np.where(j <= p, table[p, np.minimum(j, p)], 0)
        total += cf * cp * (1 << j)
    return total


# ---------------------------------------------------------------------------
# public engine
# ---------------------------------------------------------------------------

def count_packed(A: jax.Array, cand: jax.Array, l: int,
                 method: str = "auto", et: bool = True,
                 interpret: Optional[bool] = None,
                 backend: Optional[str] = None):
    """Device step over one packed batch.

    Returns (hard (B,) uint32 kernel counts with 2-plex tiles masked to 0,
    nv, t, f) -- the host combines them with the exact int64 closed form.
    All-device, no int64 (TPU-friendly); jit/pjit-able as a unit.
    ``backend`` selects the kernel implementation (see
    :mod:`repro.kernels.ops`); ``interpret`` is the deprecated alias.
    """
    T = A.shape[1]
    B = A.shape[0]
    if l == 0:
        one = jnp.ones(B, dtype=jnp.uint32)
        z = jnp.zeros(B, dtype=jnp.int32)
        return one, z, z, z
    if l == 1:
        n = popcount(cand).sum(-1).astype(jnp.uint32)
        z = jnp.zeros(B, dtype=jnp.int32)
        return n, z, z, z
    if l == 2:
        from ..kernels.ref import edges_within_ref
        n = edges_within_ref(A, cand)
        z = jnp.zeros(B, dtype=jnp.int32)
        return n, z, z, z
    nv, t, f = plex_stats(A, cand)
    if et:
        is2 = t <= 2
        hard = kops.count_tiles(A, jnp.where(is2[:, None], jnp.uint32(0),
                                             cand), l,
                                method=method, backend=backend,
                                interpret=interpret)
    else:
        hard = kops.count_tiles(A, cand, l, method=method, backend=backend,
                                interpret=interpret)
    return hard, nv, t, f


def combine_counts(hard, nv, t, f, l: int, et: bool) -> int:
    """Host-exact combination of the device step outputs."""
    hard = np.asarray(hard).astype(np.int64)
    if not et or l <= 2:
        return int(hard.sum())
    nv = np.asarray(nv)
    t = np.asarray(t)
    f = np.asarray(f)
    is2 = t <= 2
    closed = count_2plex_closed_np(nv[is2], f[is2], l)
    return int(hard.sum() + closed.sum())


def count_spilled(tile: tiles_mod.Tile, order: str, l: int, stats: Stats,
                  et_t: int, use_rule2: bool) -> int:
    """Host bitset recursion for one oversize tile (mirrors the host path).

    Each spill is recorded once: ``spilled_tiles`` counts it and
    ``spill_sizes`` keeps its width, so host-recursion work stays
    attributable (and schedulable) separately from the device batches --
    the subtree's branch stats accumulate into the same ``stats`` but the
    spill itself is never double-counted across devices.
    """
    stats.spilled_tiles += 1
    stats.spill_sizes.append(tile.s)
    cand = (1 << tile.s) - 1
    if order == "truss":
        return count_rec_T(tile.edges_ranked, cand, tile.s, l, stats,
                           et_t=et_t)
    return count_rec_C(tile.rows, cand, l, stats, colors=tile.colors,
                       et_t=et_t, use_rule2=use_rule2)


def count(g: Graph, k: int, order: str = "hybrid", et_t: int = 3,
          use_rule2: bool = True, method: str = "auto",
          interpret: Optional[bool] = None, et_route: bool = True,
          plan: Optional[pipeline.PipelinePlan] = None,
          batch_size: Optional[int] = None,
          bins: Optional[Sequence[int]] = None,
          stage_times: Optional[Dict[str, float]] = None,
          devices=None, async_staging: bool = True,
          backend: Optional[str] = None,
          pack_workers: Optional[int] = None,
          prefetch: Optional[int] = None,
          plan_cache: bool = True,
          plan_cache_dir: Optional[str] = None):
    """Full-graph k-clique count on the accelerator engine.

    Streams capacity-batched packed tiles from :mod:`repro.core.pipeline`;
    pass a prebuilt ``plan`` to amortize preprocessing across queries.
    With ``plan=None`` the engine consults the keyed plan cache
    (``pipeline.cached_plan``; disable with ``plan_cache=False``) so a
    repeated query on the same graph skips the O(delta*m) decomposition --
    ``stats.plan_cache_hit`` / ``stats.plan_build_s`` report which path
    ran, and ``plan_cache_dir`` adds an on-disk plan store shared across
    processes.  Packing runs on a parallel producer (``pack_workers``
    threads, default auto; ``0`` forces the serial packer) that keeps up
    to ``prefetch`` packed batches ahead of device dispatch.

    Oversize tiles are counted on the host (``stats.spilled_tiles`` /
    ``stats.spill_sizes``).  ``stage_times`` (optional dict) accumulates
    extract/pack/device/combine wall-clock seconds.  ``backend`` selects
    the kernel implementation family (``repro.kernels.ops`` registry;
    default auto = compiled lax off-TPU); the resolved name and first-call
    compile seconds are reported in ``stats.backend`` /
    ``stats.kernel_compile_s``.

    ``devices`` routes the packed batches through the multi-device
    dispatcher (:mod:`repro.runtime.dispatch`): an int n / ``"all"`` / a
    device list shards batches across those devices with per-device jit
    and double-buffered host->device staging (``async_staging=False``
    forces synchronous staging).  ``devices=None`` keeps the single-device
    inline path.  Counts are identical either way -- device partials are
    combined exactly on the host.

    Geometry knobs left ``None`` (``batch_size``, ``bins``,
    ``pack_workers``, ``prefetch``) resolve through the persistent
    autotuner (:func:`repro.tune.search.resolve_geometry`): explicit
    argument > persisted geometry record > the historical hardcoded
    defaults.  The count is identical under every geometry.
    """
    from .ebbkc import Result
    stats = Stats()
    stats.backend = kops.resolve_backend(backend, interpret)
    if k == 1:
        return Result(g.n, stats)
    if k == 2:
        return Result(g.m, stats)
    if plan is None and plan_cache:
        plan = pipeline.cached_plan(g, order=order,
                                    cache_dir=plan_cache_dir, stats=stats)
    total = 0
    ntiles = 0
    max_tile = 0
    l = k - 2
    et = et_route and et_t >= 2
    geom = tune_search.resolve_geometry(
        "count", l, batch_size=batch_size, bins=bins,
        pack_workers=pack_workers, prefetch=prefetch)
    stream = pipeline.stream_batches(plan or g, k, order=order,
                                     use_rule2=use_rule2,
                                     batch_size=geom.batch_size,
                                     bins=geom.bins,
                                     timings=stage_times,
                                     pack_workers=geom.pack_workers,
                                     prefetch=geom.prefetch, stats=stats)
    if devices is not None:
        from ..runtime.dispatch import Dispatcher
        disp = Dispatcher(l, devices, et=et, method=method,
                          interpret=interpret, backend=backend,
                          async_staging=async_staging,
                          stats=stats, stage_times=stage_times)
        spill_total = 0

        def on_spill(tile: tiles_mod.Tile) -> None:
            nonlocal spill_total
            spill_total += count_spilled(tile, order, l, stats, et_t,
                                         use_rule2)

        try:
            ntiles, max_tile = disp.consume(stream, on_spill=on_spill)
            total = spill_total + disp.finish()
        finally:
            stream.close()  # stops parallel-producer workers on error too
        stats.kernel_compile_s += kops.consume_compile_s()
        kops.drain_tune_events(stats)
        return Result(total, stats, ntiles, max_tile)
    for item in stream:
        if isinstance(item, tiles_mod.Tile):
            ntiles += 1
            max_tile = max(max_tile, item.s)
            total += count_spilled(item, order, l, stats, et_t, use_rule2)
            continue
        ntiles += item.B
        max_tile = max(max_tile, item.T)
        t0 = time.perf_counter()
        # batch-shape bucketing: ragged tail chunks pad to pow2 so they
        # reuse the executables of the full chunks (padding counts 0)
        hard, nv, t, f = count_packed(
            jnp.asarray(bucket_rows(item.A)),
            jnp.asarray(bucket_rows(item.cand)), l,
            method=method, et=et, interpret=interpret, backend=backend)
        if stage_times is not None:
            # async dispatch: block so device time is not billed to combine
            jax.block_until_ready((hard, nv, t, f))
        t1 = time.perf_counter()
        total += combine_counts(hard, nv, t, f, l, et)
        if stage_times is not None:
            stage_times["device"] = stage_times.get("device", 0.) + t1 - t0
            stage_times["combine"] = stage_times.get("combine", 0.) \
                + time.perf_counter() - t1
    stats.kernel_compile_s += kops.consume_compile_s()
    kops.drain_tune_events(stats)
    return Result(total, stats, ntiles, max_tile)
