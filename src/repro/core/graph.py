"""Graph container and basic decompositions (host side).

The preprocessing phase of EBBkC (truss decomposition, degeneracy ordering,
greedy coloring) is O(delta*m) work with irregular data-dependent updates --
in a production deployment it runs on the host data pipeline (CPU), exactly
like the paper's C++ preprocessing, while the exponential enumeration phase
runs on the accelerator.  A vectorized JAX truss variant lives in
``repro.core.truss_jax`` for fully on-device pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected simple graph in canonical form.

    edges: (m, 2) int64, u < v, lexicographically sorted, unique.
    indptr/indices: CSR over both directions, neighbor lists sorted.
    """

    n: int
    edges: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray

    @property
    def m(self) -> int:
        return int(self.edges.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def edge_keys(self) -> np.ndarray:
        """Canonical int64 key u*n+v (u<v) per edge, sorted ascending."""
        return self.edges[:, 0] * np.int64(self.n) + self.edges[:, 1]

    def has_edges(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Vectorized membership test for vertex pairs (any order)."""
        a = np.minimum(u, v).astype(np.int64)
        b = np.maximum(u, v).astype(np.int64)
        keys = a * np.int64(self.n) + b
        ek = self.edge_keys()
        pos = np.searchsorted(ek, keys)
        pos = np.clip(pos, 0, len(ek) - 1)
        return (ek[pos] == keys) & (a != b)

    def edge_ids(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Edge index for pairs known to be edges (canonical order enforced)."""
        a = np.minimum(u, v).astype(np.int64)
        b = np.maximum(u, v).astype(np.int64)
        keys = a * np.int64(self.n) + b
        return np.searchsorted(self.edge_keys(), keys)


def ragged_expand(counts: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(owner, position-within-segment) index arrays for ragged segments.

    The bulk-CSR-expansion idiom shared by the tile pipeline and
    truss.edge_supports: one np.repeat/cumsum pass replaces a Python loop
    over segments.
    """
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    owner = np.repeat(np.arange(counts.size, dtype=np.int64), counts)
    seg = np.repeat(np.cumsum(counts) - counts, counts)
    pos = np.arange(total, dtype=np.int64) - seg
    return owner, pos


def from_edges(n: int, edges: Iterable[Tuple[int, int]] | np.ndarray) -> Graph:
    e = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges,
                   dtype=np.int64).reshape(-1, 2)
    if e.size:
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        keep = lo != hi  # drop self loops
        lo, hi = lo[keep], hi[keep]
        keys = lo * np.int64(n) + hi
        keys = np.unique(keys)
        lo, hi = keys // n, keys % n
        e = np.stack([lo, hi], axis=1)
    else:
        e = np.zeros((0, 2), dtype=np.int64)
    # CSR over both directions
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n=n, edges=e, indptr=indptr, indices=dst)


def _canon_keys(n: int, pairs, name: str) -> np.ndarray:
    """Canonical sorted-unique edge keys (u*n+v, u<v) for a pair batch.

    Self loops are dropped; endpoints outside ``[0, n)`` raise (an edge
    batch can never grow the vertex set -- delta plans key on ``n``).
    """
    if pairs is None:
        return np.zeros(0, dtype=np.int64)
    e = np.asarray(list(pairs) if not isinstance(pairs, np.ndarray)
                   else pairs, dtype=np.int64).reshape(-1, 2)
    if e.size == 0:
        return np.zeros(0, dtype=np.int64)
    if e.min() < 0 or e.max() >= n:
        raise ValueError(
            f"{name} batch references vertices outside [0, {n})")
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    keep = lo != hi
    return np.unique(lo[keep] * np.int64(n) + hi[keep])


def apply_edge_batch(g: Graph, insert=None, delete=None) -> Graph:
    """Functional edge mutation: a new canonical Graph, same vertex set.

    ``insert`` / ``delete`` are iterables (or arrays) of vertex pairs in
    any orientation.  Deletes are applied first, then inserts; inserting
    a present edge or deleting an absent one is a no-op, so the batch is
    idempotent.  A pair appearing in both is inserted (insert wins).
    This is the mutable-graph seam the incremental plan index
    (:mod:`repro.delta`) maintains tiles over -- the returned graph is in
    the exact canonical form :func:`from_edges` produces, so plans built
    on it are byte-identical to from-scratch plans of the same edge set.
    """
    delk = _canon_keys(g.n, delete, "delete")
    insk = _canon_keys(g.n, insert, "insert")
    keys = g.edge_keys()
    if delk.size:
        keys = np.setdiff1d(keys, delk, assume_unique=True)
    if insk.size:
        keys = np.union1d(keys, insk)
    edges = np.stack([keys // np.int64(g.n), keys % np.int64(g.n)], axis=1)
    return from_edges(g.n, edges)


def degeneracy_order(g: Graph) -> Tuple[np.ndarray, int]:
    """Bucket peeling. Returns (order, delta): order[i] = i-th removed vertex.

    Every vertex has <= delta neighbors later in the order.
    """
    n = g.n
    deg = g.degrees().astype(np.int64).copy()
    maxdeg = int(deg.max()) if n else 0
    # bucket lists
    bucket_head = np.full(maxdeg + 2, -1, dtype=np.int64)
    nxt = np.full(n, -1, dtype=np.int64)
    prv = np.full(n, -1, dtype=np.int64)
    for v in range(n):
        d = deg[v]
        nxt[v] = bucket_head[d]
        if bucket_head[d] != -1:
            prv[bucket_head[d]] = v
        bucket_head[d] = v
    removed = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    delta = 0
    cur = 0
    for i in range(n):
        while cur <= maxdeg and bucket_head[cur] == -1:
            cur += 1
        v = int(bucket_head[cur])
        delta = max(delta, cur)
        # pop v
        bucket_head[cur] = nxt[v]
        if nxt[v] != -1:
            prv[nxt[v]] = -1
        removed[v] = True
        order[i] = v
        for w in g.neighbors(v):
            if removed[w]:
                continue
            d = deg[w]
            # unlink w from bucket d
            if prv[w] != -1:
                nxt[prv[w]] = nxt[w]
            else:
                bucket_head[d] = nxt[w]
            if nxt[w] != -1:
                prv[nxt[w]] = prv[w]
            deg[w] = d - 1
            # push w to bucket d-1
            prv[w] = -1
            nxt[w] = bucket_head[d - 1]
            if bucket_head[d - 1] != -1:
                prv[bucket_head[d - 1]] = w
            bucket_head[d - 1] = w
            if d - 1 < cur:
                cur = d - 1
    return order, delta


def greedy_coloring(g: Graph, order: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, int]:
    """Greedy color in reverse degeneracy order -> <= delta+1 colors.

    Returns (colors starting at 1, num_colors). Paper Section 4.3.
    """
    if order is None:
        order, _ = degeneracy_order(g)
    colors = np.zeros(g.n, dtype=np.int64)
    for v in order[::-1]:
        used = set()
        for w in g.neighbors(int(v)):
            c = colors[w]
            if c:
                used.add(int(c))
        c = 1
        while c in used:
            c += 1
        colors[int(v)] = c
    return colors, int(colors.max()) if g.n else 0


def color_vertex_order(colors: np.ndarray) -> np.ndarray:
    """Non-increasing color, ties by vertex id. Returns order array."""
    n = len(colors)
    return np.lexsort((np.arange(n), -colors))


def max_clique_size(g: Graph, ub: Optional[int] = None) -> int:
    """omega via simple BB with greedy-color bound (small graphs / stats only)."""
    order, delta = degeneracy_order(g)
    colors, _ = greedy_coloring(g, order)
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    best = 0
    rank = np.empty(g.n, dtype=np.int64)
    rank[order] = np.arange(g.n)

    def expand(cand, size):
        nonlocal best
        if size + len(cand) <= best:
            return
        # color bound
        cs = sorted({int(colors[v]) for v in cand}, reverse=True)
        if size + len(cs) <= best:
            return
        for i, v in enumerate(sorted(cand, key=lambda x: -colors[x])):
            if size + len(cand) - i <= best:
                return
            nc = [w for w in cand if w in adj[v] and rank[w] > rank[v]]
            if size + 1 > best:
                best = size + 1
            expand(nc, size + 1)

    for v in order:
        cand = [w for w in adj[int(v)] if rank[w] > rank[int(v)]]
        expand(cand, 1)
        if ub is not None and best >= ub:
            break
    return best
