"""Clique emission subsystem: device listing kernels -> global ids -> sinks.

The counting engine (:mod:`repro.core.engine_jax`) reduces every tile to a
scalar; this module is its *output* twin (DESIGN.md section 6).  The same
streaming tile pipeline feeds the Pallas listing kernel family
(:mod:`repro.kernels.clique_list`), which materializes each completed
l-clique's local vertex ids into a fixed-capacity per-tile buffer; the host
then decodes tile-local ids through the batch's ``verts`` membership table
back to global vertex ids and streams the rows into a pluggable
:class:`CliqueSink`.

Exactness invariants:

* **exact-once** -- each k-clique is produced by exactly one anchor edge
  (the paper's Eq. 2 attribution), so no de-duplication is ever needed;
* **never truncated** -- emit buffers are sized by a first count pass
  (rounded to a power of two to bound jit recompiles, capped at
  ``max_capacity``); a tile whose true count exceeds its buffer raises the
  kernel's overflow flag and is re-listed by the host bitset recursion
  (``Stats.overflowed_tiles``), exactly like oversize tiles spill
  (``Stats.spilled_tiles``).  The sink sees every clique either way;
* **deterministic order** -- rows arrive in stream order (spill tiles,
  then packed batches per size bin; tiles in batch order inside each
  batch; each row sorted ascending), invariant to device count and
  staging mode.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from .bitops import unpack_mask
from .engine_np import Stats, list_rec_C
from .graph import ragged_expand
from . import pipeline
from . import tiles as tiles_mod
from ..kernels import ops as kops
from ..obs import trace
from ..resilience import retry as fault_retry
from ..tune import search as tune_search

#: default cap on the per-tile emit buffer (rows); tiles whose true count
#: exceeds it overflow to the host spill path instead of growing VMEM
MAX_CAPACITY = 1 << 14


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


class CliqueSink:
    """Pluggable consumer of decoded clique rows.

    ``emit`` receives an ``(n, k) int64`` array of global vertex ids (rows
    sorted ascending) and returns how many rows it accepted; ``full`` lets
    bounded sinks stop the producer early.  ``bytes_written`` accounts the
    payload bytes of accepted rows (surfaced as ``Stats.sink_bytes``).
    """

    def __init__(self) -> None:
        self.accepted = 0
        self.bytes_written = 0

    @property
    def full(self) -> bool:
        """True when the sink wants no more rows (stops the producer)."""
        return False

    def emit(self, cliques: np.ndarray) -> int:
        """Consume an ``(n, k)`` rows chunk; return rows accepted."""
        raise NotImplementedError

    def close(self) -> None:
        """Flush/finalize; called once after the stream ends."""
        pass

    def _account(self, arr: np.ndarray) -> int:
        self.accepted += arr.shape[0]
        self.bytes_written += arr.nbytes
        return arr.shape[0]


class CallbackSink(CliqueSink):
    """Invoke ``fn(rows)`` for every emitted chunk (streaming consumers)."""

    def __init__(self, fn: Callable[[np.ndarray], None]) -> None:
        super().__init__()
        self.fn = fn

    def emit(self, cliques: np.ndarray) -> int:
        """Forward a non-empty chunk to the callback; accept all rows."""
        if cliques.shape[0]:
            self.fn(cliques)
        return self._account(cliques)


class ArraySink(CliqueSink):
    """Bounded in-memory buffer; backs ``list_cliques(max_out=...)``."""

    def __init__(self, k: int, max_out: Optional[int] = None) -> None:
        super().__init__()
        self.k = int(k)
        self.max_out = max_out
        self._chunks: List[np.ndarray] = []

    @property
    def full(self) -> bool:
        """True once ``max_out`` rows have been accepted."""
        return self.max_out is not None and self.accepted >= self.max_out

    def emit(self, cliques: np.ndarray) -> int:
        """Buffer rows, truncating at ``max_out``; return rows kept."""
        if self.max_out is not None:
            cliques = cliques[: max(self.max_out - self.accepted, 0)]
        if cliques.shape[0]:
            self._chunks.append(cliques)
        return self._account(cliques)

    def result(self) -> np.ndarray:
        """All accepted rows as one ``(n, k) int64`` array."""
        if not self._chunks:
            return np.zeros((0, self.k), dtype=np.int64)
        return np.concatenate(self._chunks)


class NpzSink(CliqueSink):
    """Accumulate rows and write one NPZ (key ``cliques``) on ``close``."""

    def __init__(self, path: str, k: int, max_out: Optional[int] = None) -> None:
        super().__init__()
        self.path = path
        self._inner = ArraySink(k, max_out=max_out)

    @property
    def full(self) -> bool:
        """Delegates to the buffering inner sink."""
        return self._inner.full

    def emit(self, cliques: np.ndarray) -> int:
        """Buffer rows (via an inner :class:`ArraySink`); return kept."""
        n = self._inner.emit(cliques)
        self.accepted = self._inner.accepted
        self.bytes_written = self._inner.bytes_written
        return n

    def close(self) -> None:
        """Write the buffered rows to ``path`` (NPZ key ``cliques``)."""
        np.savez_compressed(self.path, cliques=self._inner.result())


# ---------------------------------------------------------------------------
# decode: tile-local kernel output -> sorted global id rows
# ---------------------------------------------------------------------------


def _rows_from_packed(A_tile: np.ndarray, s: int) -> List[int]:
    """(T, W) uint32 packed adjacency -> python-int bitset rows [0..s)."""
    return [unpack_mask(A_tile[i]) for i in range(s)]


def _decode_local(
    anchor: np.ndarray, verts: np.ndarray, local: np.ndarray
) -> np.ndarray:
    """One tile: (n, l) local ids -> (n, 2+l) sorted global rows."""
    if local.shape[0] == 0:
        return np.zeros((0, 2 + local.shape[1]), dtype=np.int64)
    glob = verts[local]
    out = np.concatenate(
        [np.broadcast_to(anchor, (local.shape[0], 2)), glob],
        axis=1,
    )
    return np.sort(out, axis=1)


def _list_tile_host(
    rows: Sequence[int],
    s: int,
    anchor: np.ndarray,
    verts: np.ndarray,
    l: int,
    et_t: int = 3,
) -> np.ndarray:
    """Host bitset recursion listing for one tile (spill/overflow path)."""
    local: List[tuple] = []
    list_rec_C(rows, (1 << s) - 1, l, (), local, et_t=et_t)
    loc = np.asarray(local, dtype=np.int64).reshape(-1, l)
    return _decode_local(np.asarray(anchor, dtype=np.int64), verts, loc)


def list_spilled(
    tile: tiles_mod.Tile, l: int, stats: Stats, et_t: int = 3
) -> np.ndarray:
    """List one oversize tile on the host (mirrors ``count_spilled``)."""
    stats.spilled_tiles += 1
    stats.spill_sizes.append(tile.s)
    with trace.span("spill/list", s=tile.s):
        return _list_tile_host(
            tile.rows,
            tile.s,
            np.asarray(tile.anchor, dtype=np.int64),
            tile.verts,
            l,
            et_t=et_t,
        )


def decode_batch(
    batch: pipeline.TileBatch,
    bufs: np.ndarray,
    counts: np.ndarray,
    overflow: np.ndarray,
    l: int,
    stats: Stats,
    et_t: int = 3,
) -> np.ndarray:
    """Decode one harvested (buffer, count, overflow) triple to global rows.

    Non-overflowed tiles decode vectorized straight from the kernel buffer;
    overflowed tiles are re-listed by the host recursion from the packed
    adjacency (never truncated) and spliced back in tile order.
    """
    counts = np.asarray(counts, dtype=np.int64)
    overflow = np.asarray(overflow)
    counts_eff = np.where(overflow > 0, 0, counts)
    owner, pos = ragged_expand(counts_eff)
    local = bufs[owner, pos]  # (n, l) local ids
    glob = batch.verts[owner[:, None], local]
    decoded = np.concatenate([batch.anchors[owner], glob], axis=1)
    decoded = np.sort(decoded, axis=1) if decoded.shape[0] else decoded
    if not overflow.any():
        return decoded
    parts = np.split(decoded, np.cumsum(counts_eff)[:-1])
    for b in np.nonzero(overflow)[0]:
        stats.overflowed_tiles += 1
        s = int(batch.sizes[b])
        rows = _rows_from_packed(batch.A[b], s)
        with trace.span("overflow/relist", s=s):
            parts[b] = _list_tile_host(
                rows, s, batch.anchors[b], batch.verts[b], l, et_t=et_t
            )
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# capacity sizing
# ---------------------------------------------------------------------------


def capacity_for(
    counts: np.ndarray, max_capacity: int = MAX_CAPACITY, policy: str = "pow2"
) -> int:
    """Emit-buffer rows for a batch, rounded up under ``policy``.

    ``"pow2"`` (default) keeps the number of distinct (T, capacity) kernel
    shapes -- and hence jit recompiles -- logarithmic; ``"mult64"`` rounds
    to the next multiple of 64, trading more signatures for up to 2x less
    buffer riding the DFS carry.  Which wins is hardware-dependent, so the
    geometry tuner (:mod:`repro.tune.search`) owns the choice.
    ``max_capacity`` bounds VMEM either way, overflowing the rare monster
    tile to the host spill path instead.
    """
    m = int(np.asarray(counts).max(initial=1))
    if policy == "mult64":
        cap = -(-m // 64) * 64
    elif policy == "pow2":
        cap = 1
        while cap < m:
            cap *= 2
    else:
        raise ValueError(
            f"unknown capacity policy {policy!r}; expected 'pow2' or 'mult64'"
        )
    return max(1, min(cap, int(max_capacity)))


# ---------------------------------------------------------------------------
# streaming engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ListResult:
    """What ``stream_cliques`` hands back (the sink holds the rows)."""

    stats: Stats
    tiles: int = 0
    max_tile: int = 0


def _emit(sink: CliqueSink, arr: np.ndarray, stats: Stats) -> None:
    fault_retry.consume("sink.write")
    stats.emitted_cliques += sink.emit(arr)


def host_list_triple(batch: pipeline.TileBatch, l: int):
    """List an entire batch on the host, as a kernel-shaped triple.

    The last rung of the listing demotion ladder (DESIGN.md section 12):
    when every device backend has failed for a batch, each tile is listed
    by the ``et_t=0`` bitset recursion -- which emits local cliques in the
    same order as the device list kernels -- and packed into
    ``(bufs, counts, overflow)`` exactly as a device harvest would return
    them (local int32 indices, ``overflow == 0``).  Any downstream decode
    path therefore produces rows byte-identical to a fault-free run.
    """
    per: List[np.ndarray] = []
    for b in range(batch.B):
        s = int(batch.sizes[b])
        rows = _rows_from_packed(batch.A[b], s)
        local: List[tuple] = []
        list_rec_C(rows, (1 << s) - 1, l, (), local, et_t=0)
        per.append(np.asarray(local, dtype=np.int32).reshape(-1, l))
    cap = max(1, max((p.shape[0] for p in per), default=1))
    bufs = np.zeros((batch.B, cap, l), dtype=np.int32)
    counts = np.zeros(batch.B, dtype=np.int64)
    for b, p in enumerate(per):
        bufs[b, : p.shape[0]] = p
        counts[b] = p.shape[0]
    return bufs, counts, np.zeros(batch.B, dtype=np.uint32)


def list_batch(
    batch: pipeline.TileBatch,
    l: int,
    stats: Stats,
    *,
    capacity: Optional[int] = None,
    max_capacity: int = MAX_CAPACITY,
    cap_policy: str = "pow2",
    interpret: Optional[bool] = None,
    backend: Optional[str] = None,
    et_t: int = 3,
) -> np.ndarray:
    """Single-device emit step: count pass -> sized list kernel -> decode.

    The batch axis is padded to a power of two before the kernels so
    ragged tail chunks reuse the full-batch executables; the padded
    zero-candidate lanes count 0, never overflow, and are sliced off
    before decode.
    """
    from .engine_jax import bucket_rows

    B = batch.B
    A = jnp.asarray(bucket_rows(batch.A))
    cand = jnp.asarray(bucket_rows(batch.cand))
    if capacity is None:
        with trace.span("device/sizing", B=B, T=batch.T):
            counts = np.asarray(
                kops.count_tiles(
                    A, cand, l, backend=backend, interpret=interpret
                )
            )
        cap = capacity_for(counts, max_capacity, policy=cap_policy)
    else:
        cap = max(1, int(capacity))
    with trace.span("device/wait", B=B, T=batch.T, capacity=cap):
        bufs, cnt, ovf = kops.list_tiles(
            A, cand, l, capacity=cap, backend=backend, interpret=interpret
        )
        bufs, cnt, ovf = (
            np.asarray(bufs)[:B],
            np.asarray(cnt)[:B],
            np.asarray(ovf)[:B],
        )
    with trace.span("decode", B=B, T=batch.T):
        return decode_batch(batch, bufs, cnt, ovf, l, stats, et_t=et_t)


def stream_cliques(
    source,
    k: int,
    sink: CliqueSink,
    *,
    order: str = "hybrid",
    use_rule2: bool = True,
    et_t: int = 3,
    batch_size: Optional[int] = None,
    bins: Optional[Sequence[int]] = None,
    capacity: Optional[int] = None,
    max_capacity: Optional[int] = None,
    cap_policy: Optional[str] = None,
    devices=None,
    async_staging: bool = True,
    max_inflight: int = 2,
    interpret: Optional[bool] = None,
    backend: Optional[str] = None,
    stage_times: Optional[dict] = None,
    pack_workers: Optional[int] = None,
    prefetch: Optional[int] = None,
    plan_cache: bool = True,
    plan_cache_dir: Optional[str] = None,
) -> ListResult:
    """List all k-cliques of ``source`` (Graph or PipelinePlan) into ``sink``.

    The accelerator twin of ``ebbkc.list_cliques(backend="host")``: streams
    capacity-batched packed tiles, runs the listing kernels (sized by a
    first count pass unless ``capacity`` pins the buffer or selects the
    dispatcher's ``"speculative"`` ratchet mode), decodes on the host,
    and feeds the sink in deterministic stream order.  ``devices``
    routes batches through :class:`repro.runtime.dispatch.ListDispatcher`
    (per-device placement, double-buffered staging, FIFO harvest +
    decode-worker overlap -- same knobs as the counting engine).
    ``backend`` selects the kernel implementation (``repro.kernels.ops``
    registry; emitted rows are
    byte-identical across backends).  Requires k >= 3 (the k <= 2 cases
    have closed forms; see ``ebbkc.list_cliques``).

    Front-end knobs mirror ``engine_jax.count``: ``pack_workers`` /
    ``prefetch`` run packing on the parallel producer ahead of device
    dispatch (0 = serial; the emitted row stream is identical either
    way), and a Graph ``source`` consults the keyed plan cache
    (``plan_cache=False`` opts out; ``plan_cache_dir`` adds the on-disk
    store) so warm queries skip the O(delta*m) decomposition.

    Geometry knobs left ``None`` (``batch_size``, ``bins``,
    ``max_capacity``, ``cap_policy``, ``pack_workers``, ``prefetch``)
    resolve through the persistent autotuner
    (:func:`repro.tune.search.resolve_geometry`): explicit argument >
    persisted geometry record > the historical hardcoded defaults.  The
    emitted row stream is identical under every geometry.
    """
    if k < 3:
        raise ValueError("stream_cliques requires k >= 3")
    if isinstance(capacity, str):
        if capacity not in ("sized", "speculative"):
            raise ValueError(f"capacity must be None, 'sized', "
                             f"'speculative', or an int, got {capacity!r}")
        if devices is None:
            # dispatcher modes; the inline path's exact count-pass sizing
            # covers both aliases
            capacity = None
    stats = Stats()
    stats.backend = kops.resolve_backend(backend, interpret)
    res = ListResult(stats)
    l = k - 2
    geom = tune_search.resolve_geometry(
        "list",
        l,
        batch_size=batch_size,
        bins=bins,
        cap_policy=cap_policy,
        max_capacity=max_capacity,
        pack_workers=pack_workers,
        prefetch=prefetch,
    )
    if not isinstance(source, pipeline.PipelinePlan) and plan_cache:
        source = pipeline.cached_plan(source, order=order,
                                      cache_dir=plan_cache_dir, stats=stats)
    stream = pipeline.stream_batches(
        source,
        k,
        order=order,
        use_rule2=use_rule2,
        batch_size=geom.batch_size,
        bins=geom.bins,
        timings=stage_times,
        pack_workers=geom.pack_workers,
        prefetch=geom.prefetch,
        stats=stats,
    )
    if devices is not None:
        from ..runtime.dispatch import ListDispatcher

        disp = ListDispatcher(
            l,
            devices,
            sink=sink,
            stats=stats,
            capacity=capacity,
            max_capacity=geom.max_capacity,
            cap_policy=geom.cap_policy,
            interpret=interpret,
            backend=backend,
            async_staging=async_staging,
            max_inflight=max_inflight,
            et_t=et_t,
            stage_times=stage_times,
        )

        def on_spill(tile: tiles_mod.Tile) -> None:
            # host listing runs here (consumer thread); the emit goes
            # through the dispatcher's decode worker so the rows keep
            # their FIFO position relative to batch decodes
            disp.emit_rows(list_spilled(tile, l, stats, et_t=et_t))

        try:
            res.tiles, res.max_tile = disp.consume(stream, on_spill=on_spill)
            disp.finish()
        finally:
            # error path: stop the decode worker from emitting into the
            # caller's sink and cancel queued pack work; both are no-ops
            # after a clean finish
            disp.close()
            stream.close()
    else:
        try:
            for item in stream:
                if sink.full:
                    break
                if isinstance(item, tiles_mod.Tile):
                    res.tiles += 1
                    res.max_tile = max(res.max_tile, item.s)
                    _emit(sink, list_spilled(item, l, stats, et_t=et_t),
                          stats)
                    continue
                res.tiles += item.B
                res.max_tile = max(res.max_tile, item.T)
                arr = list_batch(
                    item,
                    l,
                    stats,
                    capacity=capacity,
                    max_capacity=geom.max_capacity,
                    cap_policy=geom.cap_policy,
                    interpret=interpret,
                    backend=backend,
                    et_t=et_t,
                )
                _emit(sink, arr, stats)
        finally:
            stream.close()  # shuts down any parallel-producer workers
    stats.sink_bytes += sink.bytes_written
    stats.kernel_compile_s += kops.consume_compile_s()
    kops.drain_tune_events(stats)
    return res
