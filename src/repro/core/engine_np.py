"""Host branch-and-bound engines over python-int bitset tiles.

These are the paper-faithful recursions (Algorithms 2-5) used for the
benchmark suite, plus the VBBkC baseline (Algorithm 1 family).  Bitsets are
python ints: AND / popcount run at C speed, mirroring the bitmap adjacency of
BitCol / SDegree that the paper compares against.

Three inner recursions:

* ``count_rec_T``   -- truss-ordered edge-oriented branching with the
                       explicit E(g)-filtered sub-branch construction of
                       Algorithm 3 (ESet semantics).
* ``count_rec_C``   -- color-ordered edge-oriented branching on a DAG
                       (Algorithm 4), with pruning Rules (1) and (2).
* ``count_rec_V``   -- vertex-oriented branching (Algorithm 1 = VBBkC) with
                       optional color pruning (DDegCol+ ablation).

All support early termination into ``repro.core.plex``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .bitops import bits, mask_gt, popcount
from . import plex


@dataclasses.dataclass
class Stats:
    branches: int = 0        # BB branches formed
    et_hits: int = 0         # branches finished by early termination
    pruned_size: int = 0     # pruned by |V(g)| < l
    pruned_color: int = 0    # pruned by Rules (1)/(2)
    peak_graph: int = 0      # largest branch graph seen (roofline proxy)
    spilled_tiles: int = 0   # oversize tiles routed device -> host recursion
    # sizes of the spilled tiles (one entry per spill; host-recursion cost
    # is attributable to these, separate from the device batches)
    spill_sizes: List[int] = dataclasses.field(default_factory=list)
    # multi-device dispatch accounting (repro.runtime.dispatch): device
    # ordinal -> tiles counted there / MXU-equivalent flops staged there
    device_tiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    device_flops: Dict[int, int] = dataclasses.field(default_factory=dict)
    # wall seconds the host spent NOT blocked while device work was in
    # flight -- an upper bound on the device time hidden by double-buffered
    # staging (the device may finish before the host returns for it);
    # 0.0 under synchronous staging
    staging_overlap_s: float = 0.0
    # emission subsystem accounting (repro.core.listing): cliques accepted
    # by the sink, tiles whose device emit buffer overflowed (re-listed on
    # the host -- never truncated), and bytes the sink wrote
    emitted_cliques: int = 0
    overflowed_tiles: int = 0
    sink_bytes: int = 0
    # speculative emit-capacity dispatch (repro.runtime.dispatch
    # ListDispatcher, capacity=None): batches whose capacity guess proved
    # too small and were re-listed once on the device at the exact size
    emit_retries: int = 0
    # resilience layer (repro.resilience + runtime.dispatch): device batch
    # attempts re-run after a failure (injected or real), and batches
    # demoted down the backend ladder (pallas -> lax -> ref -> host)
    retries: int = 0
    demotions: int = 0
    # kernel backend registry (repro.kernels.ops): which backend served
    # the query ("host" for the python-int recursion) and the wall seconds
    # spent on first-call kernel compilation (compile + first run, one
    # entry per (kernel, backend, shape) signature per process)
    backend: str = ""
    kernel_compile_s: float = 0.0
    # parallel front-end accounting (repro.core.pipeline.stream_batches):
    # pack-pool size this query ran with (0 = inline serial packing),
    # extract + pack seconds (worker CPU-seconds when parallel, so this
    # can exceed the wall time it was hidden under), and the prefetch
    # queue's mean occupancy (0..1 of the window) / peak depth observed at
    # consumer harvest -- ~1.0 mean means the producer kept ahead of the
    # device loop, ~0 means packing was the bottleneck
    pack_workers: int = 0
    frontend_s: float = 0.0
    pack_queue_occupancy: float = 0.0
    pack_queue_peak: int = 0
    # plan cache (repro.core.pipeline.cached_plan): True when the query's
    # preprocessing came from the keyed in-process/on-disk cache (the
    # O(delta*m) decomposition was skipped); plan_build_s is the cold-path
    # build time (0.0 on warm queries)
    plan_cache_hit: bool = False
    plan_build_s: float = 0.0
    # incremental plan maintenance (repro.delta.repair): batches repaired
    # in place vs rebuilt from scratch (churn past the threshold, or a
    # family with no local-repair path), wall seconds spent splicing, and
    # edges whose tiles were re-extracted across all repairs
    plan_repairs: int = 0
    plan_rebuilds: int = 0
    plan_repair_s: float = 0.0
    delta_touched_edges: int = 0
    # persistent autotuner (repro.tune): wall seconds spent in live tuning
    # measurements during this query (0.0 warm), and whether every tuning
    # lookup was answered from a cache layer -- False when a live
    # microbenchmark had to run, or when nothing consulted the tuner at all
    tune_s: float = 0.0
    tune_cache_hit: bool = False
    # device ordinal -> packed bytes staged there ((B,T,W) adjacency plus
    # (B,W) candidate masks); the roofline bandwidth denominator paired
    # with device_flops
    device_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)

    # How each field combines across Stats objects (Stats.merge) and how it
    # publishes to the metrics registry (repro.obs.metrics.observe_stats):
    #   sum  -- additive accumulator (counter)
    #   max  -- peak/high-water value
    #   or   -- sticky boolean flag
    #   dict -- per-key additive map (device ordinal -> amount)
    #   list -- concatenated observations (histogram)
    #   mean -- occupancy-style ratio; merge keeps the max as the
    #           conservative summary (per-run views stay exact)
    #   info -- identity metadata, kept from self (or taken from other
    #           when self is unset)
    _MERGE_KINDS = {
        "branches": "sum",
        "et_hits": "sum",
        "pruned_size": "sum",
        "pruned_color": "sum",
        "peak_graph": "max",
        "spilled_tiles": "sum",
        "spill_sizes": "list",
        "device_tiles": "dict",
        "device_flops": "dict",
        "device_bytes": "dict",
        "staging_overlap_s": "sum",
        "emitted_cliques": "sum",
        "overflowed_tiles": "sum",
        "sink_bytes": "sum",
        "emit_retries": "sum",
        "retries": "sum",
        "demotions": "sum",
        "backend": "info",
        "kernel_compile_s": "sum",
        "pack_workers": "max",
        "frontend_s": "sum",
        "pack_queue_occupancy": "mean",
        "pack_queue_peak": "max",
        "plan_cache_hit": "or",
        "plan_build_s": "sum",
        "plan_repairs": "sum",
        "plan_rebuilds": "sum",
        "plan_repair_s": "sum",
        "delta_touched_edges": "sum",
        "tune_s": "sum",
        "tune_cache_hit": "or",
    }
    # Metric-publication view of the same table (repro.obs reads this).
    _METRIC_KINDS = dict(
        _MERGE_KINDS,
        pack_workers="max",
        pack_queue_occupancy="max",
        plan_cache_hit="flag",
        tune_cache_hit="flag",
    )

    def merge(self, other: "Stats") -> "Stats":
        """Fold ``other`` into ``self`` (in place) and return ``self``.

        This is the single merge path for combining per-device /
        per-request ``Stats`` into an aggregate (``runtime.dispatch``,
        ``serve.service``, ``benchmarks``).  Every dataclass field must be
        classified in ``_MERGE_KINDS`` -- adding a field without
        classifying it raises here (and is caught by the tier-1 suite).
        """
        for f in dataclasses.fields(self):
            kind = self._MERGE_KINDS.get(f.name)
            if kind is None:
                raise TypeError(
                    f"Stats.{f.name} has no merge rule; add it to "
                    "Stats._MERGE_KINDS"
                )
            mine = getattr(self, f.name)
            theirs = getattr(other, f.name)
            if kind == "sum":
                setattr(self, f.name, mine + theirs)
            elif kind in ("max", "mean"):
                setattr(self, f.name, max(mine, theirs))
            elif kind == "or":
                setattr(self, f.name, bool(mine or theirs))
            elif kind == "dict":
                for k, v in theirs.items():
                    mine[k] = mine.get(k, 0) + v
            elif kind == "list":
                mine.extend(theirs)
            elif kind == "info":
                if not mine and theirs:
                    setattr(self, f.name, theirs)
        return self


def _count_edges(rows: Sequence[int], cand: int) -> int:
    s = 0
    for v in bits(cand):
        s += popcount(rows[v] & cand & mask_gt(v))
    return s


def _try_et(rows: Sequence[int], cand: int, l: int, et_t: int,
            stats: Stats, rec: Callable[[Sequence[int], int, int], int]
            ) -> Optional[int]:
    """Early termination (Section 5). Returns a count or None."""
    if et_t < 2:
        return None
    nv, t = plex.plexity(rows, cand)
    if nv == 0:
        return 1 if l == 0 else 0
    if t <= 2:
        stats.et_hits += 1
        return plex.count_in_2plex(rows, cand, l)
    if t <= et_t:
        # factor universal vertices combinatorially (Alg. 7 lines 8-10),
        # finish the remainder with the generic recursion
        stats.et_hits += 1
        from math import comb
        F, rest = plex.split_universal(rows, cand)
        f = popcount(F)
        total = 0
        for c in range(0, min(l, f) + 1):
            total += comb(f, c) * rec(rows, rest, l - c)
        return total
    return None


# ---------------------------------------------------------------------------
# EBBkC-C inner recursion (fixed tile adjacency, DAG by local index)
# ---------------------------------------------------------------------------

def count_rec_C(rows: Sequence[int], cand: int, l: int, stats: Stats,
                colors: Optional[Sequence[int]] = None, et_t: int = 0,
                use_rule2: bool = True) -> int:
    nv = popcount(cand)
    if nv < l:
        stats.pruned_size += 1
        return 0
    if l == 0:
        return 1
    if l == 1:
        return nv
    if l == 2:
        return _count_edges(rows, cand)
    stats.peak_graph = max(stats.peak_graph, nv)
    et = _try_et(rows, cand, l, et_t,
                 stats, lambda r, c, ll: count_rec_C(r, c, ll, stats, colors,
                                                     0, use_rule2))
    if et is not None:
        return et
    total = 0
    for u in bits(cand):
        row_u = rows[u] & cand & mask_gt(u)
        if colors is not None and colors[u] < l:  # Rule (1) part 1
            stats.pruned_color += 1
            continue
        for v in bits(row_u):
            if colors is not None and colors[v] < l - 1:  # Rule (1) part 2
                stats.pruned_color += 1
                continue
            sub = cand & rows[u] & rows[v] & mask_gt(v)
            stats.branches += 1
            if colors is not None and use_rule2:
                distinct = len({colors[w] for w in bits(sub)})
                if distinct < l - 2:  # Rule (2)
                    stats.pruned_color += 1
                    continue
            total += count_rec_C(rows, sub, l - 2, stats, colors, et_t,
                                 use_rule2)
    return total


# ---------------------------------------------------------------------------
# EBBkC-T inner recursion (edge-list filtered sub-branches, Alg. 3 semantics)
# ---------------------------------------------------------------------------

def count_rec_T(edges: List[Tuple[int, int]], cand: int, num_local: int,
                l: int, stats: Stats, et_t: int = 0) -> int:
    """edges: local pairs sorted by global pi_tau rank; cand: vertex bitset."""
    nv = popcount(cand)
    if nv < l:
        stats.pruned_size += 1
        return 0
    if l == 0:
        return 1
    if l == 1:
        return nv
    if l == 2:
        return len(edges)
    stats.peak_graph = max(stats.peak_graph, nv)
    rows = [0] * num_local
    for a, b in edges:
        rows[a] |= 1 << b
        rows[b] |= 1 << a
    if et_t >= 2:
        def rec(r, c, ll):
            sub_edges = [(a, b) for (a, b) in edges
                         if (c >> a) & 1 and (c >> b) & 1]
            return count_rec_T(sub_edges, c, num_local, ll, stats, 0)
        et = _try_et(rows, cand, l, et_t, stats, rec)
        if et is not None:
            return et
    total = 0
    for i, (a, b) in enumerate(edges):
        rows[a] &= ~(1 << b)
        rows[b] &= ~(1 << a)
        sub = rows[a] & rows[b]          # common nbrs among edges ranked > i
        stats.branches += 1
        if popcount(sub) < l - 2:
            stats.pruned_size += 1
            continue
        sub_edges = [(x, y) for (x, y) in edges[i + 1:]
                     if (sub >> x) & 1 and (sub >> y) & 1]
        total += count_rec_T(sub_edges, sub, num_local, l - 2, stats, et_t)
    return total


# ---------------------------------------------------------------------------
# VBBkC baseline inner recursion (Algorithm 1; optional color pruning)
# ---------------------------------------------------------------------------

def count_rec_V(rows: Sequence[int], cand: int, l: int, stats: Stats,
                colors: Optional[Sequence[int]] = None, et_t: int = 0,
                use_rule2: bool = False) -> int:
    nv = popcount(cand)
    if nv < l:
        stats.pruned_size += 1
        return 0
    if l == 0:
        return 1
    if l == 1:
        return nv
    if l == 2:
        return _count_edges(rows, cand)
    stats.peak_graph = max(stats.peak_graph, nv)
    et = _try_et(rows, cand, l, et_t,
                 stats, lambda r, c, ll: count_rec_V(r, c, ll, stats, colors,
                                                     0, use_rule2))
    if et is not None:
        return et
    total = 0
    for v in bits(cand):
        if colors is not None and colors[v] < l:  # VBBkC color Rule (1)
            stats.pruned_color += 1
            continue
        sub = cand & rows[v] & mask_gt(v)
        stats.branches += 1
        if colors is not None and use_rule2:
            distinct = len({colors[w] for w in bits(sub)})
            if distinct < l - 1:  # Rule (2) adapted to VBBkC (Sec. 4.3)
                stats.pruned_color += 1
                continue
        total += count_rec_V(rows, sub, l - 1, stats, colors, et_t, use_rule2)
    return total


# ---------------------------------------------------------------------------
# Listing variants (emit local-id tuples); used by the listing API and tests
# ---------------------------------------------------------------------------

def list_rec_C(rows: Sequence[int], cand: int, l: int, prefix: Tuple[int, ...],
               out: List[Tuple[int, ...]], colors=None, et_t: int = 0) -> None:
    nv = popcount(cand)
    if nv < l:
        return
    if l == 0:
        out.append(prefix)
        return
    if l == 1:
        for v in bits(cand):
            out.append(prefix + (v,))
        return
    if l == 2:
        for v in bits(cand):
            for w in bits(rows[v] & cand & mask_gt(v)):
                out.append(prefix + (v, w))
        return
    if et_t >= 2:
        nv2, t = plex.plexity(rows, cand)
        if t <= 2:
            for tup in plex.list_2plex(rows, cand, l):
                out.append(prefix + tup)
            return
        if t <= et_t:
            for tup in plex.list_tplex(rows, cand, l):
                out.append(prefix + tup)
            return
    for u in bits(cand):
        for v in bits(rows[u] & cand & mask_gt(u)):
            sub = cand & rows[u] & rows[v] & mask_gt(v)
            list_rec_C(rows, sub, l - 2, prefix + (u, v), out, colors, et_t)
