"""Top-level branch extraction: edges -> tau-bounded dense tiles.

NOTE: this module is the pure-Python *reference oracle*.  Production
consumers (host and JAX engines, launcher, service) go through the
vectorized :mod:`repro.core.pipeline`, whose parity tests assert it
reproduces these tiles exactly (same order, members, rows, colors, ranks).

This is the heart of the TPU adaptation: the first (and only data-dependent)
level of EBBkC branching is materialized as a batch of small dense subgraph
"tiles", one per edge.  With the truss-based ordering every tile has at most
tau vertices (Lemma 4.1), giving tight, similar-sized work units -- exactly
what a lockstep SPMD accelerator wants (the paper observes the same property
for its EdgeParallel scheme in Section 6.2(7)).

Extraction runs the pi_tau ordering *in reverse*, inserting edges into a
live adjacency structure: when edge e_r is visited, the structure contains
exactly the edges ranked after r, so the Alg. 3 ESet filter is free.

Modes
-----
truss  : pi_tau ordering; tile = common nbrs via edges ranked after e;
         tile edges keep their pi_tau ranks (Alg. 3 ESet semantics).
color  : global greedy coloring; DAG by color order; tile = common
         out-neighbors; Rules (1)/(2) prune whole tiles (Alg. 4).
hybrid : truss extraction + per-tile local coloring for inner pruning
         (Alg. 5) -- the paper's default EBBkC.
vertex : VBBkC baseline (Alg. 1): one tile per vertex (out-neighborhood in
         the degeneracy DAG), optionally locally colored (DDegCol).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .graph import Graph, degeneracy_order, greedy_coloring, color_vertex_order
from .truss import truss_decomposition


@dataclasses.dataclass
class Tile:
    anchor: Tuple[int, ...]          # global vertices already in S (edge or vertex)
    verts: np.ndarray                # (s,) global vertex ids, local order
    rows: List[int]                  # local adjacency bitsets (python ints)
    nedges: int
    edges_ranked: Optional[List[Tuple[int, int]]] = None  # truss-mode inner order
    colors: Optional[List[int]] = None                    # local color values

    @property
    def s(self) -> int:
        return int(len(self.verts))


def _local_color(rows: List[int], s: int) -> Tuple[List[int], List[int]]:
    """Greedy color a tile; return colors + order (color desc, id asc)."""
    from .bitops import bits
    deg = [(r.bit_count(), i) for i, r in enumerate(rows)]
    colors = [0] * s
    for _, v in sorted(deg, reverse=True):
        used = {colors[w] for w in bits(rows[v])}
        c = 1
        while c in used:
            c += 1
        colors[v] = c
    order = sorted(range(s), key=lambda v: (-colors[v], v))
    return colors, order


def _relabel(rows: List[int], order: List[int]) -> List[int]:
    """rows under permutation new_local = position in order."""
    from .bitops import bits
    s = len(rows)
    inv = [0] * s
    for new_i, old_i in enumerate(order):
        inv[old_i] = new_i
    out = [0] * s
    for new_i, old_i in enumerate(order):
        r = 0
        for old_j in bits(rows[old_i]):
            r |= 1 << inv[old_j]
        out[new_i] = r
    return out


def edge_tiles(g: Graph, k: int, mode: str = "hybrid",
               use_rule2: bool = True) -> Iterator[Tile]:
    """Yield one tile per top-level edge branch (EBBkC Eq. 2).

    Tiles are yielded in *reverse* pi_tau order for truss/hybrid modes (the
    attribution argument makes top-level order irrelevant for correctness).
    """
    if mode in ("truss", "hybrid"):
        td = truss_decomposition(g)
        alive: List[set] = [set() for _ in range(g.n)]
        rank_d = {}
        if mode == "truss":
            for r, eid in enumerate(td.order.tolist()):
                a, b = int(g.edges[eid, 0]), int(g.edges[eid, 1])
                rank_d[a * g.n + b] = r
                rank_d[b * g.n + a] = r
        for r in range(g.m - 1, -1, -1):
            eid = int(td.order[r])
            u, v = int(g.edges[eid, 0]), int(g.edges[eid, 1])
            au, av = alive[u], alive[v]
            if len(au) > len(av):
                au, av = av, au
            common = [w for w in au if w in av]
            if len(common) >= max(k - 2, 1):
                common.sort()
                s = len(common)
                idx = {w: i for i, w in enumerate(common)}
                rows = [0] * s
                pairs = []
                for i in range(s):
                    ai = alive[common[i]]
                    for j in range(i + 1, s):
                        if common[j] in ai:
                            rows[i] |= 1 << j
                            rows[j] |= 1 << i
                            pairs.append((i, j))
                verts = np.asarray(common, dtype=np.int64)
                if mode == "hybrid":
                    colors, order = _local_color(rows, s)
                    rows = _relabel(rows, order)
                    verts = verts[np.asarray(order)]
                    colors = [colors[i] for i in order]
                    yield Tile((u, v), verts, rows, len(pairs), colors=colors)
                else:
                    pr = sorted(pairs, key=lambda p: rank_d[
                        int(verts[p[0]]) * g.n + int(verts[p[1]])])
                    yield Tile((u, v), verts, rows, len(pairs),
                               edges_ranked=pr)
            alive[u].add(v)
            alive[v].add(u)
    elif mode == "color":
        colors, _ = greedy_coloring(g)
        vorder = color_vertex_order(colors)
        vid = np.empty(g.n, dtype=np.int64)
        vid[vorder] = np.arange(g.n)
        adjset = [set(g.neighbors(x).tolist()) for x in range(g.n)]
        outset = [set(w for w in adjset[x] if vid[w] > vid[x])
                  for x in range(g.n)]
        for eid in range(g.m):
            a, b = int(g.edges[eid, 0]), int(g.edges[eid, 1])
            u, v = (a, b) if vid[a] < vid[b] else (b, a)
            # Rule (1): col(u) >= k and col(v) >= k-1 required
            if colors[u] < k or colors[v] < k - 1:
                continue
            ou, ov = outset[u], outset[v]
            if len(ou) > len(ov):
                ou, ov = ov, ou
            common = [w for w in ou if w in ov]
            if len(common) < k - 2:
                continue
            common.sort(key=lambda w: int(vid[w]))
            tile_colors = [int(colors[w]) for w in common]
            if use_rule2 and len(set(tile_colors)) < k - 2:  # Rule (2)
                continue
            s = len(common)
            rows = [0] * s
            ne = 0
            for i in range(s):
                ai = adjset[common[i]]
                for j in range(i + 1, s):
                    if common[j] in ai:
                        rows[i] |= 1 << j
                        rows[j] |= 1 << i
                        ne += 1
            yield Tile((u, v), np.asarray(common, dtype=np.int64), rows, ne,
                       colors=tile_colors)
    else:
        raise ValueError(f"unknown edge-tile mode: {mode}")


def vertex_tiles(g: Graph, k: int, colored: bool = True) -> Iterator[Tile]:
    """VBBkC baseline: one tile per vertex (degeneracy DAG out-neighborhood)."""
    order, _ = degeneracy_order(g)
    rank = np.empty(g.n, dtype=np.int64)
    rank[order] = np.arange(g.n)
    adjset = [set(g.neighbors(x).tolist()) for x in range(g.n)]
    for v in order.tolist():
        verts = sorted(w for w in adjset[v] if rank[w] > rank[v])
        if len(verts) < k - 1:
            continue
        s = len(verts)
        rows = [0] * s
        ne = 0
        for i in range(s):
            ai = adjset[verts[i]]
            for j in range(i + 1, s):
                if verts[j] in ai:
                    rows[i] |= 1 << j
                    rows[j] |= 1 << i
                    ne += 1
        va = np.asarray(verts, dtype=np.int64)
        if colored:
            cols, corder = _local_color(rows, s)
            rows = _relabel(rows, corder)
            va = va[np.asarray(corder)]
            cols = [cols[i] for i in corder]
            yield Tile((v,), va, rows, ne, colors=cols)
        else:
            yield Tile((v,), va, rows, ne)
    return
