"""VBBkC baseline (paper Algorithm 1 / Section 3): vertex-oriented BB.

Variants reproduce the paper's comparison set:
  * ``degen``     -- Degen: degeneracy ordering only.
  * ``ddegcol``   -- DDegCol: degeneracy top level + per-branch color order
                     with Rule (1) pruning.
  * ``ddegcol+``  -- DDegCol plus the paper's new Rule (2) (ablation, Fig. 6).
"""
from __future__ import annotations

from .ebbkc import Result
from .engine_np import Stats, count_rec_V
from .graph import Graph
from . import tiles as tiles_mod


def count(g: Graph, k: int, variant: str = "ddegcol", et_t: int = 0) -> Result:
    if k == 1:
        return Result(g.n, Stats())
    if k == 2:
        return Result(g.m, Stats())
    colored = variant in ("ddegcol", "ddegcol+")
    use_rule2 = variant == "ddegcol+"
    stats = Stats()
    total = 0
    ntiles = 0
    max_tile = 0
    for tile in tiles_mod.vertex_tiles(g, k, colored=colored):
        ntiles += 1
        max_tile = max(max_tile, tile.s)
        cand = (1 << tile.s) - 1
        total += count_rec_V(tile.rows, cand, k - 1, stats,
                             colors=tile.colors, et_t=et_t,
                             use_rule2=use_rule2)
    return Result(total, stats, ntiles, max_tile)
