"""Pallas TPU kernel: batched edge-branch candidate construction.

The EBBkC branching step Eq. (2): for an edge (a, b) inside a tile, the
sub-branch candidate set is N(a) & N(b) restricted to later-ranked vertices.
One program per tile block; word-wise AND + popcount on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import gt_masks_np, num_words, popcount


def _kernel(A_ref, pairs_ref, gt_ref, cand_ref, n_ref, *, T: int, BT: int):
    gt = gt_ref[...]                     # (T, W)
    for i in range(BT):                  # unrolled small block
        a = pairs_ref[i, 0]
        b = pairs_ref[i, 1]
        row_a = A_ref[i, a, :]
        row_b = A_ref[i, b, :]
        cand = row_a & row_b & gt[b]
        cand_ref[i, :] = cand
        n_ref[i] = popcount(cand).sum().astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def edge_candidates(A: jax.Array, pairs: jax.Array, block: int = 8,
                    interpret: bool = True):
    """A: (B, T, W) uint32; pairs: (B, 2) int32 local vertex ids (a < b).

    Returns (cand (B, W) uint32, n (B,) uint32): candidate bitsets
    N(a) & N(b) & gt(b) and their sizes.
    """
    B, T, W = A.shape
    assert W == num_words(T) and pairs.shape == (B, 2)
    BT = min(block, B)
    pad = (-B) % BT
    if pad:
        A = jnp.pad(A, ((0, pad), (0, 0), (0, 0)))
        pairs = jnp.pad(pairs, ((0, pad), (0, 0)))
    Bp = B + pad
    gt = jnp.asarray(gt_masks_np(T))
    kernel = functools.partial(_kernel, T=T, BT=BT)
    cand, n = pl.pallas_call(
        kernel,
        grid=(Bp // BT,),
        in_specs=[
            pl.BlockSpec((BT, T, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((BT, 2), lambda b: (b, 0)),
            pl.BlockSpec((T, W), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BT, W), lambda b: (b, 0)),
            pl.BlockSpec((BT,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, W), jnp.uint32),
            jax.ShapeDtypeStruct((Bp,), jnp.uint32),
        ],
        interpret=interpret,
    )(A, pairs, gt)
    return cand[:B], n[:B]
