"""Pure-jnp oracles for every kernel (the reference the Pallas kernels must
match bit-exactly; also used directly by tests and as a CPU fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import gt_masks_np, popcount, unpack_bits


def edges_within_ref(A: jax.Array, cand: jax.Array) -> jax.Array:
    """(B,T,W),(B,W) -> (B,) edge count of cand-induced subgraph."""
    B, T, W = A.shape
    gt = jnp.asarray(gt_masks_np(T))
    rows = A & cand[:, None, :] & gt[None]
    per_v = popcount(rows).sum(-1)                      # (B, T)
    vbit = unpack_bits(cand, T)                         # (B, T)
    return (per_v * vbit).sum(-1).astype(jnp.uint32)


def triangle_count_tiles_ref(A: jax.Array, cand: jax.Array) -> jax.Array:
    B, T, W = A.shape
    M = unpack_bits(A, T).astype(jnp.float32)
    c = unpack_bits(cand, T).astype(jnp.float32)
    M = M * c[:, :, None] * c[:, None, :]
    tri = jnp.einsum("bij,bjk,bik->b", M, M, M) / 6.0
    return tri.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("l",))
def clique_count_tiles_ref(A: jax.Array, cand: jax.Array, l: int) -> jax.Array:
    """Vectorized expansion recursion (memory O(B * T^(l-2)); tests only)."""
    B, T, W = A.shape
    gt = jnp.asarray(gt_masks_np(T))
    if l == 1:
        return popcount(cand).sum(-1).astype(jnp.uint32)
    if l == 2:
        return edges_within_ref(A, cand)
    subs = cand[:, None, :] & A & gt[None]              # (B, T, W)
    vbit = unpack_bits(cand, T)                         # (B, T)
    A_rep = jnp.repeat(A, T, axis=0)                    # (B*T, T, W)
    inner = clique_count_tiles_ref(A_rep, subs.reshape(B * T, W), l - 1)
    return (inner.reshape(B, T) * vbit).sum(-1).astype(jnp.uint32)


def edge_candidates_ref(A: jax.Array, pairs: jax.Array):
    B, T, W = A.shape
    gt = jnp.asarray(gt_masks_np(T))
    row_a = jnp.take_along_axis(A, pairs[:, 0][:, None, None].astype(jnp.int32)
                                .repeat(W, axis=2), axis=1)[:, 0]
    row_b = jnp.take_along_axis(A, pairs[:, 1][:, None, None].astype(jnp.int32)
                                .repeat(W, axis=2), axis=1)[:, 0]
    gt_b = gt[pairs[:, 1].astype(jnp.int32)]
    cand = row_a & row_b & gt_b
    return cand, popcount(cand).sum(-1).astype(jnp.uint32)
