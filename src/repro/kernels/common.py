"""Shared helpers for the bitset clique kernels.

The bit-manipulation primitives live in :mod:`repro.core.bitops` (one
module, one test); this module re-exports them under the names the kernels
historically used (``popcount`` here is the *traced* per-word popcount)
plus the kernel-only combinatorics table.
"""

from __future__ import annotations

import numpy as np

from ..core.bitops import (  # noqa: F401  (re-exported kernel API)
    WORD,
    bit_at,
    gt_masks_np,
    num_words,
    unpack_bits,
)
from ..core.bitops import popcount_words as popcount  # noqa: F401


def pascal_table(nmax: int) -> np.ndarray:
    """C(n, r) table, int64, (nmax+1, nmax+1); entries that overflow clamp."""
    t = np.zeros((nmax + 1, nmax + 1), dtype=np.int64)
    t[:, 0] = 1
    for n in range(1, nmax + 1):
        for r in range(1, n + 1):
            v = t[n - 1, r - 1] + t[n - 1, r]
            t[n, r] = v
    return t
