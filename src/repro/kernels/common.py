"""Shared helpers for the bitset clique kernels.

The bit-manipulation primitives live in :mod:`repro.core.bitops` (one
module, one test); this module re-exports them under the names the kernels
historically used (``popcount`` here is the *traced* per-word popcount)
plus the kernel-only combinatorics table.

This module is also the single home of the *base-case set math* shared by
the Pallas kernels (:mod:`repro.kernels.clique_count` /
:mod:`repro.kernels.clique_list`) and the compiled lax backend
(:mod:`repro.kernels.lax_backend`): the vectorized edge / triangle counts
of a candidate-induced subgraph and the fixed-capacity emit scatters.
Sharing one definition is what makes the backends byte-identical -- the
listing buffers are filled by the exact same index arithmetic everywhere.
"""

from __future__ import annotations

import numpy as np

from ..core.bitops import (  # noqa: F401  (re-exported kernel API)
    WORD,
    bit_at,
    gt_masks_np,
    num_words,
    unpack_bits,
)
from ..core.bitops import popcount_words as popcount  # noqa: F401


# ---------------------------------------------------------------------------
# vectorized base-case closes (traced; shared by Pallas and lax backends)
# ---------------------------------------------------------------------------


def member_rows(A, cand):
    """Rows of the cand-induced subgraph: A[v] & cand, zeroed for v not in
    cand.  A: (T, W) uint32, cand: (W,).  Returns (T, W) uint32."""
    import jax.numpy as jnp

    T = A.shape[0]
    vbit = unpack_bits(cand, T)                  # (T,)
    rows = A & cand[None, :]
    return jnp.where(vbit[:, None] > 0, rows, jnp.uint32(0))


def edges_within(A, cand, gt):
    """Vectorized edge count of the cand-induced subgraph (each pair once).

    A: (T, W) uint32, cand: (W,), gt: (T, W). Returns uint32 scalar.
    """
    import jax.numpy as jnp

    T = A.shape[0]
    rows = A & cand[None, :] & gt                # (T, W) neighbors>v in cand
    per_v = popcount(rows).sum(axis=-1)          # (T,)
    vbit = unpack_bits(cand, T)                  # (T,)
    return jnp.sum(per_v * vbit).astype(jnp.uint32)


def triangles_within(A, cand, gt):
    """Vectorized triangle count of the cand-induced subgraph (each once).

    The l'==3 base-case close: every triangle v<u<w is attributed to its
    edge (v, u) and counted as |N(v) & N(u) & cand & gt(u)| -- one
    (T, T, W) word-AND + popcount instead of a tau/2-wide scalar DFS level.
    A: (T, W) uint32, cand: (W,), gt: (T, W).  Returns uint32 scalar.
    """
    import jax.numpy as jnp

    T = A.shape[0]
    rows = member_rows(A, cand)                  # (T, W)
    # [v, u] -> packed {w : w in N(v) & N(u) & cand, w > u}
    pair = rows[:, None, :] & rows[None, :, :] & gt[None, :, :]
    cnt = popcount(pair).sum(-1).astype(jnp.uint32)   # (T, T)
    adj = unpack_bits(rows & gt, T)              # (T, T): edge v<u in cand
    return (adj * cnt).sum().astype(jnp.uint32)


# ---------------------------------------------------------------------------
# fixed-capacity emit scatters (traced; shared by Pallas and lax listing)
# ---------------------------------------------------------------------------


def _scatter_rows(buf, count, flat, coords, prefix, npfx: int, capacity: int):
    """Scatter rows ``prefix[:npfx] + coords_i`` for every set flat[i].

    flat: (N,) int32 0/1 emission mask in lexicographic row order;
    coords: list of (N,) int32 coordinate columns completing the prefix.
    Rows land at ``count + rank``; ranks past ``capacity`` are dropped by
    the scatter (mode="drop") while the returned count keeps the true
    total -- the overflow contract of the listing kernels.
    """
    import jax.numpy as jnp

    N = flat.shape[0]
    dest = jnp.where(
        flat > 0,
        count.astype(jnp.int32) + jnp.cumsum(flat) - 1,
        jnp.int32(capacity),  # out of bounds -> dropped
    )
    cols = [jnp.broadcast_to(prefix[:npfx], (N, npfx))] if npfx else []
    cols.extend(c[:, None] for c in coords)
    rows = jnp.concatenate(cols, axis=1) if len(cols) > 1 else cols[0]
    buf = buf.at[dest].set(rows, mode="drop")
    return buf, count + flat.sum().astype(jnp.uint32)


def emit_frontier(buf, count, cand, prefix, *, l: int, T: int, capacity: int):
    """l'==1 close: every cand vertex completes the prefix (one column)."""
    import jax
    import jax.numpy as jnp

    vbit = unpack_bits(cand, T).astype(jnp.int32)     # (T,)
    iota = jax.lax.iota(jnp.int32, T)
    return _scatter_rows(buf, count, vbit, [iota], prefix, l - 1, capacity)


def emit_edges(buf, count, A, cand, gt, prefix, *, l: int, T: int,
               capacity: int):
    """l'==2 close: every edge (u, w), u<w, of the cand-induced subgraph
    completes the prefix -- a (T, T) dense mask, flattened in lex order."""
    import jax
    import jax.numpy as jnp

    rows = member_rows(A, cand)
    e = unpack_bits(rows & gt, T).astype(jnp.int32)   # (T, T) edge u<w
    iota = jax.lax.iota(jnp.int32, T)
    u = jnp.broadcast_to(iota[:, None], (T, T)).reshape(-1)
    w = jnp.broadcast_to(iota[None, :], (T, T)).reshape(-1)
    return _scatter_rows(buf, count, e.reshape(-1), [u, w], prefix, l - 2,
                         capacity)


def emit_triangles(buf, count, A, cand, gt, prefix, *, l: int, T: int,
                   capacity: int):
    """Whole-tile triangle emit: every triangle (v, u, w), v<u<w, of the
    cand-induced subgraph completes the prefix, in lexicographic order.

    Output-sensitive *gather* formulation, O(T^2 W + capacity) instead of
    the dense O(T^3) lex mask: triangle ranks come from a T^2 cumsum of
    per-edge completion counts (the packed (T, T, W) pair intersection,
    never unpacked), and each of the ``capacity`` output slots *gathers*
    its rank-r triangle -- pair via searchsorted over the rank prefix, w
    via word-level prefix + in-word select-by-rank.  Work therefore scales
    with the buffer actually produced, not with bin-width^3 (tiles sit in
    pow2 bins up to 8x wider than their vertex count).

    Contract: called once per tile top level with ``count == 0`` and an
    all-zero ``buf`` (the l >= 4 DFS closes with :func:`emit_edges`
    instead).  Returns the filled (capacity, l) buffer (rows past
    min(total, capacity) stay zero) and the TRUE triangle total.
    """
    import jax
    import jax.numpy as jnp

    W = num_words(T)
    rows = member_rows(A, cand)
    edge_vu = unpack_bits(rows & gt, T)                          # (T, T)
    pair = rows[:, None, :] & rows[None, :, :] & gt[None, :, :]  # (T, T, W)
    pair = jnp.where(edge_vu[:, :, None] > 0, pair, jnp.uint32(0))
    wcnt = popcount(pair).astype(jnp.int32)                      # (T, T, W)
    flat_cnt = wcnt.sum(-1).reshape(-1)                          # (T*T,)
    base = jnp.cumsum(flat_cnt)                                  # inclusive
    total = base[-1]
    # rank -> pair map without a log-factor search: scatter each nonempty
    # pair's index at its first rank, then running-max fills the segment
    # (starts are strictly increasing across nonempty pairs)
    starts = base - flat_cnt                                     # exclusive
    pids = jnp.arange(T * T, dtype=jnp.int32)
    slot_at = jnp.where(flat_cnt > 0, starts, jnp.int32(capacity))
    p = jnp.zeros((capacity,), dtype=jnp.int32).at[slot_at].max(
        pids, mode="drop")
    p = jax.lax.cummax(p)                                        # (cap,)
    ranks = jnp.arange(capacity, dtype=jnp.int32)
    k = ranks - starts[p]                        # rank within the pair
    v = p // T
    u = p % T
    words = pair.reshape(T * T, W)[p]                            # (cap, W)
    if W == 1:
        kw = k
        wrd = jnp.zeros_like(k)
        word = words[:, 0]
    else:
        wc = popcount(words).astype(jnp.int32)
        wbase = jnp.cumsum(wc, axis=-1) - wc                     # exclusive
        # containing word: last j with wbase[j] <= k (empty words collapse)
        wrd = jnp.sum((wbase <= k[:, None]).astype(jnp.int32), -1) - 1
        kw = k - jnp.take_along_axis(wbase, wrd[:, None], axis=-1)[:, 0]
        word = jnp.take_along_axis(words, wrd[:, None], axis=-1)[:, 0]
    # (kw+1)-th set bit of ``word``: branchless 5-step binary select over
    # popcount halves (garbage past the true count; masked below)
    pos = jnp.zeros_like(kw)
    w32 = word
    for half in (16, 8, 4, 2, 1):
        low = w32 & jnp.uint32((1 << half) - 1)
        c = popcount(low).astype(jnp.int32)
        go = kw >= c
        kw = kw - jnp.where(go, c, 0)
        pos = pos + jnp.where(go, half, 0)
        w32 = jnp.where(go, w32 >> jnp.uint32(half), low)
    w = wrd * WORD + pos
    valid = ranks < jnp.minimum(total, jnp.int32(capacity))
    npfx = l - 3
    cols = ([jnp.broadcast_to(prefix[:npfx], (capacity, npfx))]
            if npfx else [])
    cols.extend(c[:, None] for c in (v, u, w))
    out = jnp.concatenate(cols, axis=1)
    out = jnp.where(valid[:, None], out, buf)
    return out, count + total.astype(jnp.uint32)


def pascal_table(nmax: int) -> np.ndarray:
    """C(n, r) table, int64, (nmax+1, nmax+1); entries that overflow clamp."""
    t = np.zeros((nmax + 1, nmax + 1), dtype=np.int64)
    t[:, 0] = 1
    for n in range(1, nmax + 1):
        for r in range(1, n + 1):
            v = t[n - 1, r - 1] + t[n - 1, r]
            t[n, r] = v
    return t
