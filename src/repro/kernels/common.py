"""Shared helpers for the bitset clique kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


def num_words(T: int) -> int:
    assert T % WORD == 0, "tile size must be a multiple of 32"
    return T // WORD


def gt_masks_np(T: int) -> np.ndarray:
    """(T, W) uint32: gt[v] has exactly the bits {v+1, ..., T-1} set."""
    W = num_words(T)
    out = np.zeros((T, W), dtype=np.uint32)
    for v in range(T):
        for w in range(W):
            word = 0
            for j in range(WORD):
                if w * WORD + j > v:
                    word |= 1 << j
            out[v, w] = word
    return out


def popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)


def unpack_bits(x: jax.Array, T: int) -> jax.Array:
    """(..., W) uint32 -> (..., T) {0,1} uint32 (bit j of word w -> w*32+j)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (x[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*x.shape[:-1], T)


def bit_at(x: jax.Array, v) -> jax.Array:
    """Extract bit v (scalar, possibly traced) from packed (..., W) uint32."""
    v = jnp.asarray(v, dtype=jnp.int32)
    word = jnp.take(x, v // WORD, axis=-1)
    return (word >> (v % WORD).astype(jnp.uint32)) & jnp.uint32(1)


def pascal_table(nmax: int) -> np.ndarray:
    """C(n, r) table, int64, (nmax+1, nmax+1); entries that overflow clamp."""
    t = np.zeros((nmax + 1, nmax + 1), dtype=np.int64)
    t[:, 0] = 1
    for n in range(1, nmax + 1):
        for r in range(1, n + 1):
            v = t[n - 1, r - 1] + t[n - 1, r]
            t[n, r] = v
    return t
