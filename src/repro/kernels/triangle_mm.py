"""Pallas TPU kernel: batched masked triangle counting on the MXU.

Beyond-paper optimization (see EXPERIMENTS.md section Perf): the l==3 base
case of the clique DFS -- by far the most-executed branch shape -- is
reformulated from bitset intersections (VPU, ~T*W word-ops per vertex) to a
dense masked matmul (MXU):

    tri(tile) = sum((M @ M) * M) / 6,   M = unpack(A) * cand * cand^T

TPU MXU does the (T, T) @ (T, T) product at bf16/f32 throughput; for T=128
this is 2*128^3 = 4.2 MFLOP per tile at 197 TFLOP/s vs ~T^2*W = 2k word-ops
on the VPU.  The kernel processes a block of BT tiles per program so the MXU
sees a well-shaped batch.

Exactness: counts accumulate in f32; per-tile triangle count <= C(128, 3)
= 341k < 2^24, so f32 is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import num_words, unpack_bits


def _kernel(A_ref, cand_ref, out_ref, *, T: int, BT: int, dtype):
    A = A_ref[...]                              # (BT, T, W) uint32
    cand = cand_ref[...]                        # (BT, W)
    # candidate masking entirely in the packed-bit domain (word AND for
    # columns, predicated rows): ONE unpack, no (BT,T,T) float mask passes
    Am = A & cand[:, None, :]                   # column mask, uint32 words
    cbit = unpack_bits(cand, T)                 # (BT, T) {0,1}
    Am = jnp.where(cbit[:, :, None] > 0, Am, jnp.uint32(0))  # row mask
    M = unpack_bits(Am, T).astype(dtype)        # (BT, T, T) fully masked
    # {0,1} operands: bf16 is exact and native MXU dtype; accumulate f32
    P = jax.lax.dot_general(
        M, M, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)     # (BT, T, T) batched matmul
    tri = jnp.einsum("bij,bij->b", P, M.astype(jnp.float32)) / 6.0
    out_ref[...] = tri.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "dtype"))
def triangle_count_tiles(A: jax.Array, cand: jax.Array, block: int = 8,
                         interpret: bool = True,
                         dtype=jnp.bfloat16) -> jax.Array:
    """(B, T, W) uint32, (B, W) uint32 -> (B,) uint32 triangle counts."""
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    BT = min(block, B)
    pad = (-B) % BT
    if pad:
        A = jnp.pad(A, ((0, pad), (0, 0), (0, 0)))
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
    Bp = B + pad
    kernel = functools.partial(_kernel, T=T, BT=BT, dtype=dtype)
    out = pl.pallas_call(
        kernel,
        grid=(Bp // BT,),
        in_specs=[
            pl.BlockSpec((BT, T, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((BT, W), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((BT,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.uint32),
        interpret=interpret,
    )(A, cand)
    return out[:B]
