"""Compiled ``jax.lax`` kernel backend: the bitset DFS without Pallas.

On this CPU container every Pallas invocation runs in interpret mode (the
kernel body executes in Python), so the paper's exponential hot loop is
dominated by interpreter overhead.  This module expresses the exact same
word-wise bitset DFS -- counting and listing -- in pure ``jax.lax``
(explicit-stack ``while_loop`` ``vmap``ped over the batch axis with masked
lanes), jit-compiled to native XLA:CPU/GPU code.  Same inputs, same
fixed-capacity ``(B, capacity, l)`` buffer contract, byte-identical
outputs; no Pallas, no interpreter.

Two structural changes make the compiled path fast:

* **Lifted base case** (shared with the Pallas kernels via
  :mod:`repro.kernels.common`): a branch closes as soon as *three* levels
  remain, with the closed-form triangle count / vectorized triangle emit
  over the candidate-induced subgraph -- one (T, T, W) word-AND + popcount
  (plus a (T, T, T) lex-order scatter when listing) instead of the deepest
  and widest scalar DFS level.  l <= 3 therefore never enters the loop at
  all: the whole tile is one fused vectorized op.
* **Frontier-vectorized stepping**: the DFS stack stores *todo* frontier
  bitsets rather than cursors; each iteration extracts the lowest set bit
  (word-parallel), so the loop runs one iteration per actual branch, not
  per vertex slot.  Every iteration is branch-free (``where``-selected
  push/close/pop), which is exactly what ``vmap`` wants: lanes that
  finished early ride along masked instead of forcing per-lane ``cond``
  branches into ``select``-both-sides.

Batch hygiene: callers stream many distinct batch sizes (ragged tails,
hypothesis graphs), and XLA compiles one executable per shape -- so the
public entry points pad the batch axis up to a power of two (zero-``cand``
lanes are exactly count-neutral and emit nothing) and chunk very large
(B, T) combinations to bound the transient (T, T, T) emit memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import (
    WORD,
    edges_within,
    emit_edges,
    emit_frontier,
    emit_triangles,
    gt_masks_np,
    num_words,
    popcount,
    triangles_within,
)

#: soft cap (bytes) on the per-chunk transient emit mask; the (T, T, T)
#: int32 lex-order scatter is the largest intermediate of the listing path
_EMIT_BYTES_BUDGET = 256 << 20


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_lanes(A, cand, B_to: int):
    """Zero-pad the batch axis to ``B_to`` lanes (cand == 0 is neutral)."""
    pad = B_to - A.shape[0]
    if pad == 0:
        return A, cand
    A = jnp.pad(A, ((0, pad), (0, 0), (0, 0)))
    cand = jnp.pad(cand, ((0, pad), (0, 0)))
    return A, cand


def _list_chunk_lanes(T: int, l: int) -> int:
    """Lanes per jitted listing call so the packed (T, T, W) pair
    intersections and per-slot gather transients of the emit stay within
    the budget; always a power of two >= 1."""
    per_lane = (T * T) * (T // 32 * 8 + 16) + 64
    lanes = max(1, _EMIT_BYTES_BUDGET // per_lane)
    p = 1
    while p * 2 <= lanes:
        p *= 2
    return min(p, 1024)


def _lowest_set(todo):
    """Extract the lowest set bit of a packed (W,) bitset.

    Returns (any_bit bool, v int32 vertex id, after (W,) todo minus v).
    With an empty todo: any_bit False, v out of range, after all-zero --
    callers mask on any_bit.
    """
    nz = todo != jnp.uint32(0)
    any_bit = nz.any()
    w_idx = jnp.argmax(nz).astype(jnp.int32)
    word = todo[w_idx]
    lsb = word & (jnp.uint32(0) - word)
    tz = popcount(lsb - jnp.uint32(1)).astype(jnp.int32)
    v = w_idx * WORD + tz
    after = todo.at[w_idx].set(word & (word - jnp.uint32(1)))
    return any_bit, v, after


# ---------------------------------------------------------------------------
# counting
# ---------------------------------------------------------------------------


def _count_tile_dfs(A, cand, gt, l: int):
    """Per-tile l-clique count, l >= 4: explicit-todo-stack DFS closed two
    levels early by the triangle form.  Uniform branch-free iteration:
    consume the lowest frontier bit, close its sub-branch when three
    levels would remain, push otherwise, pop on an empty frontier."""
    W = cand.shape[0]
    S = l - 3  # stack depths 0..l-4; a sub-branch at 3 remaining closes
    stack0 = jnp.zeros((S, W), dtype=jnp.uint32).at[0].set(cand)
    state0 = (jnp.int32(0), stack0, jnp.uint32(0))

    def cond(state):
        return state[0] >= 0

    def body(state):
        depth, stack, count = state
        todo = jax.lax.dynamic_index_in_dim(stack, depth, 0, keepdims=False)
        any_bit, v, after = _lowest_set(todo)
        row_v = jax.lax.dynamic_index_in_dim(A, v, 0, keepdims=False)
        sub = after & row_v            # cand & N(v) & gt(v)
        closing = depth == (l - 4)     # sub would have 3 levels remaining
        tri = triangles_within(A, sub, gt)
        count = count + jnp.where(any_bit & closing, tri, jnp.uint32(0))
        nsub = popcount(sub).sum().astype(jnp.int32)
        push = any_bit & (~closing) & (nsub >= l - depth - 1)
        stack = jax.lax.dynamic_update_index_in_dim(stack, after, depth, 0)
        nxt = jnp.where(push, depth + 1, depth)
        stack = jax.lax.dynamic_update_index_in_dim(
            stack, jnp.where(push, sub, after), nxt, 0)
        depth = jnp.where(any_bit, jnp.where(push, depth + 1, depth),
                          depth - 1)
        return depth, stack, count

    _, _, count = jax.lax.while_loop(cond, body, state0)
    return count


@functools.partial(jax.jit, static_argnames=("l",))
def _count_batch(A, cand, l: int):
    B, T, W = A.shape
    gt = jnp.asarray(gt_masks_np(T))
    if l == 1:
        return popcount(cand).sum(-1).astype(jnp.uint32)
    if l == 2:
        return jax.vmap(lambda a, c: edges_within(a, c, gt))(A, cand)
    if l == 3:
        return jax.vmap(lambda a, c: triangles_within(a, c, gt))(A, cand)
    return jax.vmap(lambda a, c: _count_tile_dfs(a, c, gt, l))(A, cand)


def count_tiles(A: jax.Array, cand: jax.Array, l: int) -> jax.Array:
    """Count l-cliques per tile. (B,T,W) uint32 x (B,W) uint32 -> (B,) u32.

    Same contract as the Pallas kernels, compiled to native code.
    """
    if l < 1:
        raise ValueError("lax counting backend requires l >= 1")
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    Bp = _pow2_ceil(max(B, 1))
    A, cand = _pad_lanes(jnp.asarray(A), jnp.asarray(cand), Bp)
    return _count_batch(A, cand, l)[:B]


# ---------------------------------------------------------------------------
# listing
# ---------------------------------------------------------------------------


def _list_tile_dfs(A, cand, gt, l: int, capacity: int):
    """Per-tile listing, l >= 4: same DFS walk as counting but the close
    scatters the whole *edge* frontier (u', w') of the sub-branch into the
    fixed-capacity buffer, prefixed by the stacked branch vertices.

    The close fires at two-remaining rather than the counting path's
    three-remaining: the emit runs on *every* loop iteration (vmap turns a
    ``cond`` into compute-both-sides), so its per-step footprint must stay
    (T, T)-shaped -- the dense (T, T, T) triangle scatter is reserved for
    the l == 3 top level where it runs exactly once per tile.  Relative to
    the pre-lift kernel this still deletes the deepest scalar level: the
    old DFS stepped vertex-by-vertex through two-remaining and only
    vectorized the final one-remaining frontier."""
    W = cand.shape[0]
    S = l - 2  # stack depths 0..l-3; a sub-branch at 2 remaining closes
    stack0 = jnp.zeros((S, W), dtype=jnp.uint32).at[0].set(cand)
    prefix0 = jnp.zeros((S,), dtype=jnp.int32)
    buf0 = jnp.zeros((capacity, l), dtype=jnp.int32)
    state0 = (jnp.int32(0), stack0, prefix0, buf0, jnp.uint32(0))

    def cond(state):
        return state[0] >= 0

    def body(state):
        depth, stack, prefix, buf, count = state
        todo = jax.lax.dynamic_index_in_dim(stack, depth, 0, keepdims=False)
        any_bit, v, after = _lowest_set(todo)
        row_v = jax.lax.dynamic_index_in_dim(A, v, 0, keepdims=False)
        sub = after & row_v
        closing = depth == (l - 3)
        prefix = jax.lax.dynamic_update_index_in_dim(prefix, v, depth, 0)
        # emission is unconditional but masked: a zeroed frontier scatters
        # nothing and leaves count unchanged (vmap-friendly, no cond)
        emit_cand = jnp.where(any_bit & closing, sub, jnp.uint32(0))
        buf, count = emit_edges(
            buf, count, A, emit_cand, gt, prefix,
            l=l, T=A.shape[0], capacity=capacity)
        nsub = popcount(sub).sum().astype(jnp.int32)
        push = any_bit & (~closing) & (nsub >= l - depth - 1)
        stack = jax.lax.dynamic_update_index_in_dim(stack, after, depth, 0)
        nxt = jnp.where(push, depth + 1, depth)
        stack = jax.lax.dynamic_update_index_in_dim(
            stack, jnp.where(push, sub, after), nxt, 0)
        depth = jnp.where(any_bit, jnp.where(push, depth + 1, depth),
                          depth - 1)
        return depth, stack, prefix, buf, count

    _, _, _, buf, count = jax.lax.while_loop(cond, body, state0)
    return buf, count


@functools.partial(jax.jit, static_argnames=("l", "capacity"))
def _list_batch(A, cand, l: int, capacity: int):
    B, T, W = A.shape
    gt = jnp.asarray(gt_masks_np(T))
    zbuf = jnp.zeros((capacity, l), dtype=jnp.int32)
    zpfx = jnp.zeros((max(l, 1),), dtype=jnp.int32)
    zcnt = jnp.uint32(0)
    if l == 1:
        def one(a, c):
            return emit_frontier(zbuf, zcnt, c, zpfx, l=l, T=T,
                                 capacity=capacity)
    elif l == 2:
        def one(a, c):
            return emit_edges(zbuf, zcnt, a, c, gt, zpfx, l=l, T=T,
                              capacity=capacity)
    elif l == 3:
        def one(a, c):
            return emit_triangles(zbuf, zcnt, a, c, gt, zpfx, l=l, T=T,
                                  capacity=capacity)
    else:
        def one(a, c):
            return _list_tile_dfs(a, c, gt, l, capacity)
    buf, count = jax.vmap(one)(A, cand)
    overflow = (count > jnp.uint32(capacity)).astype(jnp.uint32)
    return buf, count, overflow


def list_tiles(A: jax.Array, cand: jax.Array, l: int, capacity: int):
    """List l-cliques per tile into fixed-capacity local-id buffers.

    Same contract (and byte-identical buffers) as
    :func:`repro.kernels.clique_list.clique_list_tiles`: returns
    (out (B, capacity, l) int32, count (B,) uint32 TRUE totals,
    overflow (B,) uint32).  Large (B, T) combinations are processed in
    equal power-of-two lane chunks so the transient (T, T, T) emit mask
    stays within a fixed memory budget; chunking is invisible in the
    output.
    """
    if l < 1:
        raise ValueError("listing kernel requires l >= 1")
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    A = jnp.asarray(A)
    cand = jnp.asarray(cand)
    chunk = min(_pow2_ceil(max(B, 1)), _list_chunk_lanes(T, l))
    Bp = -(-B // chunk) * chunk
    A, cand = _pad_lanes(A, cand, Bp)
    outs = [
        _list_batch(A[i:i + chunk], cand[i:i + chunk], l, capacity)
        for i in range(0, Bp, chunk)
    ]
    if len(outs) == 1:
        buf, cnt, ovf = outs[0]
    else:
        buf = jnp.concatenate([o[0] for o in outs])
        cnt = jnp.concatenate([o[1] for o in outs])
        ovf = jnp.concatenate([o[2] for o in outs])
    return buf[:B], cnt[:B], ovf[:B]


__all__ = ["count_tiles", "list_tiles"]
