"""Pallas TPU kernel: l-clique counting inside dense bitset tiles.

This is the paper's exponential hot loop, adapted to TPU.  Each grid program
owns one tile: a packed adjacency bitmap ``A (T, W=T/32) uint32`` plus a
candidate bitset ``cand (W,)``.  The per-branch set intersection of EBBkC
(``g' = g & N(u) & N(v)``) becomes word-wise AND + popcount on the VPU; the
recursion becomes an explicit-stack DFS inside a ``lax.while_loop`` (TPU
scalar core drives the loop, vector core does the (T, W) base-case math).

The DFS enumerates vertices in local order (attribution by rank handled by
the caller's ordering), descending until *three* levels remain; the l'==3
base case is the closed-form triangle count of the candidate-induced
subgraph (a (T, T, W) row-AND + popcount, see
:func:`repro.kernels.common.triangles_within`) -- one vectorized op instead
of a tau/2-wide scalar DFS level stepping through l'==2.  l == 3 therefore
never enters the loop at all, and k = 5 counting (l = 3) is a single
vectorized close per tile.  The same base-case math is shared with the
compiled lax backend (:mod:`repro.kernels.lax_backend`), keeping the two
backends bit-identical.

VMEM footprint per program: A block T*W*4 bytes (<= 128*4*4 = 2 KiB) +
gt mask (T, W) + stack ((l+1) * W words) -- tiny; many programs per core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (WORD, edges_within, gt_masks_np, num_words, popcount,
                     triangles_within)

# backward-compat alias (pre-registry name used by older call sites/tests)
_edges_within = edges_within


def _kernel(A_ref, cand_ref, gt_ref, out_ref, *, l: int, T: int):
    W = num_words(T)
    A = A_ref[0]                   # (T, W)
    cand0 = cand_ref[0]            # (W,)
    gt = gt_ref[...]               # (T, W)

    if l == 1:
        out_ref[0] = popcount(cand0).sum().astype(jnp.uint32)
        return
    if l == 2:
        out_ref[0] = edges_within(A, cand0, gt)
        return
    if l == 3:
        out_ref[0] = triangles_within(A, cand0, gt)
        return

    depth0 = jnp.int32(0)
    # stack[d] = candidate bitset at depth d; cursor[d] = next vertex to try
    stack0 = jnp.zeros((l + 1, W), dtype=jnp.uint32).at[0].set(cand0)
    cursor0 = jnp.zeros((l + 1,), dtype=jnp.int32)
    count0 = jnp.uint32(0)

    def cond(state):
        depth, _, _, _ = state
        return depth >= 0

    def body(state):
        depth, stack, cursor, count = state
        cand = stack[depth]
        remaining = l - depth

        def base3(_):
            # three levels remain: close with the vectorized triangle count
            c = triangles_within(A, cand, gt)
            return depth - 1, stack, cursor, count + c

        def step(_):
            v = cursor[depth]

            def pop(_):
                return depth - 1, stack, cursor, count

            def advance(_):
                word = cand[v // WORD]
                bit = (word >> (v % WORD).astype(jnp.uint32)) & jnp.uint32(1)
                cur2 = cursor.at[depth].set(v + 1)

                def push(_):
                    sub = cand & A[v] & gt[v]
                    nsub = popcount(sub).sum().astype(jnp.int32)
                    ok = nsub >= remaining - 1

                    def do_push(_):
                        st = stack.at[depth + 1].set(sub)
                        cu = cur2.at[depth + 1].set(v + 1)
                        return depth + 1, st, cu, count

                    return jax.lax.cond(ok, do_push,
                                        lambda _: (depth, stack, cur2, count),
                                        None)

                return jax.lax.cond(bit > 0, push,
                                    lambda _: (depth, stack, cur2, count),
                                    None)

            return jax.lax.cond(v >= T, pop, advance, None)

        return jax.lax.cond(remaining == 3, base3, step, None)

    _, _, _, count = jax.lax.while_loop(
        cond, body, (depth0, stack0, cursor0, count0))
    out_ref[0] = count


@functools.partial(jax.jit, static_argnames=("l", "interpret"))
def clique_count_tiles(A: jax.Array, cand: jax.Array, l: int,
                       interpret: bool = True) -> jax.Array:
    """Count l-cliques per tile.

    A: (B, T, W) uint32 packed adjacency, cand: (B, W) uint32.
    Returns (B,) uint32 counts.
    """
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    gt = jnp.asarray(gt_masks_np(T))
    kernel = functools.partial(_kernel, l=l, T=T)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, W), lambda b: (b, 0)),
            pl.BlockSpec((T, W), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.uint32),
        interpret=interpret,
    )(A, cand, gt)
