"""Pallas TPU kernel: l-clique counting inside dense bitset tiles.

This is the paper's exponential hot loop, adapted to TPU.  Each grid program
owns one tile: a packed adjacency bitmap ``A (T, W=T/32) uint32`` plus a
candidate bitset ``cand (W,)``.  The per-branch set intersection of EBBkC
(``g' = g & N(u) & N(v)``) becomes word-wise AND + popcount on the VPU; the
recursion becomes an explicit-stack DFS inside a ``lax.while_loop`` (TPU
scalar core drives the loop, vector core does the (T, W) base-case math).

The DFS enumerates vertices in local order (attribution by rank handled by
the caller's ordering), descending until two levels remain; the l'==2 base
case is the vectorized edge count popcount((A & cand) & gt)/1 over the whole
tile -- one (T, W) VPU op instead of tau more scalar steps.

VMEM footprint per program: A block T*W*4 bytes (<= 128*4*4 = 2 KiB) +
gt mask (T, W) + stack ((l+1) * W words) -- tiny; many programs per core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import WORD, gt_masks_np, num_words, popcount, unpack_bits


def _edges_within(A, cand, gt):
    """Vectorized edge count of the cand-induced subgraph (each pair once).

    A: (T, W) uint32, cand: (W,), gt: (T, W). Returns uint32 scalar.
    """
    T = A.shape[0]
    rows = A & cand[None, :] & gt            # (T, W) neighbors>v within cand
    per_v = popcount(rows).sum(axis=-1)      # (T,)
    vbit = unpack_bits(cand, T)              # (T,)
    return jnp.sum(per_v * vbit).astype(jnp.uint32)


def _kernel(A_ref, cand_ref, gt_ref, out_ref, *, l: int, T: int):
    W = num_words(T)
    A = A_ref[0]                   # (T, W)
    cand0 = cand_ref[0]            # (W,)
    gt = gt_ref[...]               # (T, W)

    if l == 1:
        out_ref[0] = popcount(cand0).sum().astype(jnp.uint32)
        return
    if l == 2:
        out_ref[0] = _edges_within(A, cand0, gt)
        return

    depth0 = jnp.int32(0)
    # stack[d] = candidate bitset at depth d; cursor[d] = next vertex to try
    stack0 = jnp.zeros((l + 1, W), dtype=jnp.uint32).at[0].set(cand0)
    cursor0 = jnp.zeros((l + 1,), dtype=jnp.int32)
    count0 = jnp.uint32(0)

    def cond(state):
        depth, _, _, _ = state
        return depth >= 0

    def body(state):
        depth, stack, cursor, count = state
        cand = stack[depth]
        remaining = l - depth

        def base2(_):
            # two levels remain: close with the vectorized edge count
            c = _edges_within(A, cand, gt)
            return depth - 1, stack, cursor, count + c

        def step(_):
            v = cursor[depth]

            def pop(_):
                return depth - 1, stack, cursor, count

            def advance(_):
                word = cand[v // WORD]
                bit = (word >> (v % WORD).astype(jnp.uint32)) & jnp.uint32(1)
                cur2 = cursor.at[depth].set(v + 1)

                def push(_):
                    sub = cand & A[v] & gt[v]
                    nsub = popcount(sub).sum().astype(jnp.int32)
                    ok = nsub >= remaining - 1

                    def do_push(_):
                        st = stack.at[depth + 1].set(sub)
                        cu = cur2.at[depth + 1].set(v + 1)
                        return depth + 1, st, cu, count

                    return jax.lax.cond(ok, do_push,
                                        lambda _: (depth, stack, cur2, count),
                                        None)

                return jax.lax.cond(bit > 0, push,
                                    lambda _: (depth, stack, cur2, count),
                                    None)

            return jax.lax.cond(v >= T, pop, advance, None)

        return jax.lax.cond(remaining == 2, base2, step, None)

    _, _, _, count = jax.lax.while_loop(
        cond, body, (depth0, stack0, cursor0, count0))
    out_ref[0] = count


@functools.partial(jax.jit, static_argnames=("l", "interpret"))
def clique_count_tiles(A: jax.Array, cand: jax.Array, l: int,
                       interpret: bool = True) -> jax.Array:
    """Count l-cliques per tile.

    A: (B, T, W) uint32 packed adjacency, cand: (B, W) uint32.
    Returns (B,) uint32 counts.
    """
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    gt = jnp.asarray(gt_masks_np(T))
    kernel = functools.partial(_kernel, l=l, T=T)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, W), lambda b: (b, 0)),
            pl.BlockSpec((T, W), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.uint32),
        interpret=interpret,
    )(A, cand, gt)
