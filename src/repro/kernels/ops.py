"""Backend registry + jit'd dispatch wrappers over the clique kernels.

Every kernel family (counting, listing, triangles, edge candidates) is
served by one of several interchangeable backends:

* ``"pallas"`` -- the Pallas kernels (:mod:`repro.kernels.clique_count` /
  :mod:`repro.kernels.clique_list`): compiled Mosaic on TPU, interpret
  mode elsewhere (the kernel body executes in Python -- correct but slow;
  CPU CI uses it as the reference implementation of the device path).
* ``"lax"`` -- the compiled :mod:`repro.kernels.lax_backend`: the same
  word-wise bitset DFS expressed in pure ``jax.lax`` and jit-compiled to
  native XLA:CPU/GPU code.  Byte-identical outputs, no interpreter.
* ``"ref"`` -- the pure-jnp expansion oracles (:mod:`repro.kernels.ref`,
  counting only; memory O(B * T^(l-2)), tests/cross-checks).
* ``"auto"`` (default) -- Mosaic Pallas on TPU, lax everywhere else.
* ``"autotune"`` -- per-(device kind, mode, l, T, capacity bucket)
  microbenchmark between the pallas and lax backends.  Winners are cached
  in-process and, when a tune-cache directory is configured
  (:mod:`repro.tune.cache`), persisted across processes as
  :class:`~repro.tune.records.TuningRecord` files -- a warm process never
  re-measures.

Selection precedence: explicit ``backend=`` argument > the
``REPRO_BACKEND`` environment variable (read per call; lets CI flip the
whole suite without touching call sites) > the deprecated ``interpret=``
alias (``interpret=True/False`` selects the Pallas backend with that
interpret flag, the pre-registry API) > ``"auto"``.  Inside an autotune
resolution the same ladder continues: a concrete ``REPRO_BACKEND`` value
beats a persisted record beats the live microbenchmark -- so the env knob
overrides stale tuning state even when a call site pins
``backend="autotune"``.

The module also accounts kernel compile time: the first invocation per
(function, backend, shape) signature is timed synchronously and accrued to
a process-wide counter that engines drain into ``Stats.kernel_compile_s``
via :func:`consume_compile_s`.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from . import clique_count as _cc
from . import clique_list as _cl
from . import intersect as _is
from . import lax_backend as _lax
from . import triangle_mm as _tm
from . import ref as _ref
from ..obs import profile as obs_profile
from ..obs import trace

BACKENDS = ("auto", "pallas", "lax", "ref", "autotune")

#: env var consulted when no explicit ``backend=`` is passed
BACKEND_ENV = "REPRO_BACKEND"

#: in-process autotune winners, keyed (device_kind, mode, l, T, cap_bucket)
#: -- the capacity bucket and device kind are part of the key (PR-6 fix):
#: a winner measured at one capacity regime or on one device kind is never
#: served to another
_AUTOTUNE_CACHE: Dict[Tuple[str, str, int, int, int], str] = {}
_COMPILE_S = 0.0
_SEEN_SIGNATURES = set()


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def resolve_backend(backend: Optional[str] = None,
                    interpret: Optional[bool] = None) -> str:
    """Resolve the backend knob to a registry name (see module docstring).

    ``"auto"`` resolves to a concrete backend; ``"autotune"`` is returned
    as-is (the per-shape winner is only known once l and T are).
    """
    for cand in (backend, os.environ.get(BACKEND_ENV) or None):
        if cand is None:
            continue
        if cand not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {cand!r}; expected one of {BACKENDS}")
        if cand != "auto":
            return cand
        break  # explicit "auto": skip the interpret alias
    else:
        if interpret is not None:
            return "pallas"  # deprecated alias: pin the Pallas kernels
    return "pallas" if jax.default_backend() == "tpu" else "lax"


def autotune_backend(mode: str, l: int, T: int,
                     capacity: Optional[int] = None,
                     trials: int = 2) -> str:
    """Backend winner for one kernel signature, cheapest source first.

    Resolution ladder (the tail of the module-docstring precedence):

    1. a *concrete* ``REPRO_BACKEND`` value -- the env knob overrides any
       cached or persisted winner, even under an explicit
       ``backend="autotune"`` argument;
    2. the in-process winner cache, keyed
       ``(device_kind, mode, l, T, capacity bucket)``;
    3. a persisted :class:`~repro.tune.records.TuningRecord` from the
       configured tune-cache directory (cross-process warm start);
    4. the live lax-vs-pallas microbenchmark
       (:func:`repro.tune.search.microbench_backend`), whose winner is
       written back through layers 2-3.

    Lookups and microbenchmark seconds accrue to the tuning-event
    accumulator (:func:`repro.tune.cache.note_event`) that engines drain
    into ``Stats.tune_s`` / ``tune_cache_hit``.
    """
    global _COMPILE_S
    env = os.environ.get(BACKEND_ENV) or None
    if env is not None and env in BACKENDS and env not in ("auto", "autotune"):
        return env
    from .. import tune
    from ..tune import search as tune_search

    key = (tune.device_kind(), mode, int(l), int(T),
           tune.capacity_bucket(capacity if mode == "list" else None))
    got = _AUTOTUNE_CACHE.get(key)
    if got is not None:
        tune.note_event(lookup=True)
        trace.instant("tune/cache_hit", source="memory", mode=mode, T=T)
        return got
    rkey = tune.backend_key(mode, l, T,
                            capacity if mode == "list" else None)
    rec = tune.get(rkey)
    if rec is not None and rec.data.get("winner") in ("lax", "pallas"):
        best = rec.data["winner"]
        _AUTOTUNE_CACHE[key] = best
        tune.note_event(lookup=True)
        trace.instant("tune/cache_hit", source="record", mode=mode, T=T)
        return best
    # park compile seconds accrued by earlier *real* kernel calls so the
    # drain below discards only the microbenchmark's own compiles
    pending = consume_compile_s()
    t0 = time.perf_counter()
    with trace.span("tune/microbench", mode=mode, l=l, T=T, trials=trials):
        best, times = tune_search.microbench_backend(mode, l, T,
                                                     capacity=capacity,
                                                     trials=trials)
    tune_s = time.perf_counter() - t0
    # the microbenchmark compiled both candidates through the registry;
    # drain those first-call seconds so they are not billed to whatever
    # engine query happened to trigger the autotune, then restore the
    # parked pre-autotune accrual
    consume_compile_s()
    _COMPILE_S += pending
    _AUTOTUNE_CACHE[key] = best
    tune.note_event(seconds=tune_s, lookup=True, miss=True)
    tune.put(tune.TuningRecord(
        "backend", key[0], tune.jax_version(), mode, int(l), T=int(T),
        W=int(T) // 32,
        cap_bucket=tune.capacity_bucket(capacity if mode == "list" else None),
        data={"winner": best, "times": times, "trials": trials,
              "tune_s": tune_s}))
    return best


def clear_autotune_cache() -> None:
    """Drop in-process autotune winners (persisted records survive)."""
    _AUTOTUNE_CACHE.clear()


def consume_compile_s() -> float:
    """Drain the first-call (compile + first run) seconds accumulator."""
    global _COMPILE_S
    v, _COMPILE_S = _COMPILE_S, 0.0
    return v


def consume_tune_events() -> tuple:
    """Drain tuning events -> ``(tune_s, lookups, misses)``.

    Engines call this next to :func:`consume_compile_s` and derive
    ``Stats.tune_cache_hit = lookups > 0 and misses == 0``.
    """
    from .. import tune

    return tune.consume_events()


def drain_tune_events(stats) -> None:
    """Drain tuning events into a ``Stats`` at an engine drain point.

    A drain that saw no events leaves ``tune_cache_hit`` untouched -- the
    engines and the dispatchers share one Stats and both drain, so only
    the drain that actually collected the query's lookups gets to decide
    the flag (hit = every lookup answered from a cache layer).
    """
    tune_s, lookups, misses = consume_tune_events()
    stats.tune_s += tune_s
    if lookups or misses:
        stats.tune_cache_hit = misses == 0


def _arg_device(x) -> str:
    try:
        return ",".join(sorted(str(d) for d in x.devices()))
    except Exception:
        return "host"


def _timed_first_call(key: tuple, fn, *args):
    """Time the first call per signature into the compile accumulator.

    Inside a jit trace (tracer arguments) timing is skipped -- the caller
    (e.g. the dispatcher's per-device jit) accounts its own compile.
    """
    global _COMPILE_S
    if any(isinstance(a, jax.core.Tracer) for a in args):
        return fn(*args)
    key = key + (_arg_device(args[0]),)
    if key in _SEEN_SIGNATURES:
        return fn(*args)
    sig = "/".join(str(p) for p in key)
    t0 = time.perf_counter()
    with trace.span("kernel/compile", sig=sig):
        out = jax.block_until_ready(fn(*args))
    dt = time.perf_counter() - t0
    _COMPILE_S += dt
    obs_profile.note_kernel(sig, compile_s=dt)
    _SEEN_SIGNATURES.add(key)
    return out


def count_tiles(A: jax.Array, cand: jax.Array, l: int,
                method: str = "auto", backend: Optional[str] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Count l-cliques per tile. (B,T,W) uint32 x (B,W) uint32 -> (B,) uint32.

    ``method``: "auto" routes the Pallas backend's l==3 to the MXU matmul
    kernel and other l to the bitset DFS kernel; "dfs" / "mxu" force a
    Pallas kernel path; "ref" forces the expansion oracle.  ``backend``
    selects the implementation family (see module docstring); ``interpret``
    is the deprecated pre-registry alias for ``backend="pallas"``.
    """
    T = A.shape[1]
    b = resolve_backend(backend, interpret)
    if method == "ref" or b == "ref":
        return _ref.clique_count_tiles_ref(A, cand, l)
    if l <= 2:
        # closed forms, no kernel needed on any backend
        return _ref.clique_count_tiles_ref(A, cand, l)
    if b == "autotune":
        b = autotune_backend("count", l, T)  # counting: capacity n/a
    if b == "lax" and method == "auto":
        return _timed_first_call(("count", "lax", l, A.shape),
                                 lambda a, c: _lax.count_tiles(a, c, l),
                                 A, cand)
    # Pallas family (or an explicit method= kernel pin)
    itp = _auto_interpret(interpret)
    if method == "mxu" or (method == "auto" and l == 3):
        if l != 3:
            raise ValueError("mxu path implements the l==3 base case only")
        return _timed_first_call(
            ("tri", "pallas", itp, A.shape),
            lambda a, c: _tm.triangle_count_tiles(a, c, interpret=itp),
            A, cand)
    return _timed_first_call(
        ("count", "pallas", itp, l, A.shape),
        lambda a, c: _cc.clique_count_tiles(a, c, l, interpret=itp),
        A, cand)


def list_tiles(A: jax.Array, cand: jax.Array, l: int, capacity: int,
               backend: Optional[str] = None,
               interpret: Optional[bool] = None):
    """List l-cliques per tile into fixed-capacity local-id buffers.

    (B,T,W) uint32 x (B,W) uint32 -> (out (B,capacity,l) int32,
    count (B,) uint32 true totals, overflow (B,) uint32).  Overflowed
    tiles keep the true count but only the first ``capacity`` cliques;
    callers must route them to the host spill path, never truncate.
    Buffers are byte-identical across backends.
    """
    b = resolve_backend(backend, interpret)
    if b == "ref":
        raise ValueError("the ref backend implements counting only")
    if b == "autotune":
        b = autotune_backend("list", l, A.shape[1], capacity=capacity)
    if b == "lax":
        return _timed_first_call(
            ("list", "lax", l, capacity, A.shape),
            lambda a, c: _lax.list_tiles(a, c, l, capacity),
            A, cand)
    itp = _auto_interpret(interpret)
    return _timed_first_call(
        ("list", "pallas", itp, l, capacity, A.shape),
        lambda a, c: _cl.clique_list_tiles(a, c, l, capacity, interpret=itp),
        A, cand)


def triangles(A: jax.Array, cand: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    return _tm.triangle_count_tiles(A, cand,
                                    interpret=_auto_interpret(interpret))


def edge_candidates(A: jax.Array, pairs: jax.Array,
                    interpret: Optional[bool] = None):
    return _is.edge_candidates(A, pairs, interpret=_auto_interpret(interpret))
