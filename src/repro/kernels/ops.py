"""Jit'd dispatch wrappers over the Pallas kernels.

``interpret=None`` auto-selects: compiled Mosaic on TPU, interpret mode
elsewhere (this container is CPU-only; interpret mode executes the kernel
body in Python for correctness validation, per the deliverable spec).
"""
from __future__ import annotations

from typing import Optional

import jax

from . import clique_count as _cc
from . import clique_list as _cl
from . import intersect as _is
from . import triangle_mm as _tm
from . import ref as _ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def count_tiles(A: jax.Array, cand: jax.Array, l: int,
                method: str = "auto", interpret: Optional[bool] = None
                ) -> jax.Array:
    """Count l-cliques per tile. (B,T,W) uint32 x (B,W) uint32 -> (B,) uint32.

    method: "auto" routes l==3 to the MXU matmul kernel and other l to the
    bitset DFS kernel; "dfs" / "mxu" / "ref" force a path.
    """
    interpret = _auto_interpret(interpret)
    if method == "ref":
        return _ref.clique_count_tiles_ref(A, cand, l)
    if method == "mxu" or (method == "auto" and l == 3):
        if l != 3:
            raise ValueError("mxu path implements the l==3 base case only")
        return _tm.triangle_count_tiles(A, cand, interpret=interpret)
    if l <= 2:
        return (_ref.clique_count_tiles_ref(A, cand, l) if l <= 2 else None)
    return _cc.clique_count_tiles(A, cand, l, interpret=interpret)


def list_tiles(A: jax.Array, cand: jax.Array, l: int, capacity: int,
               interpret: Optional[bool] = None):
    """List l-cliques per tile into fixed-capacity local-id buffers.

    (B,T,W) uint32 x (B,W) uint32 -> (out (B,capacity,l) int32,
    count (B,) uint32 true totals, overflow (B,) uint32).  Overflowed
    tiles keep the true count but only the first ``capacity`` cliques;
    callers must route them to the host spill path, never truncate.
    """
    return _cl.clique_list_tiles(A, cand, l, capacity,
                                 interpret=_auto_interpret(interpret))


def triangles(A: jax.Array, cand: jax.Array,
              interpret: Optional[bool] = None) -> jax.Array:
    return _tm.triangle_count_tiles(A, cand,
                                    interpret=_auto_interpret(interpret))


def edge_candidates(A: jax.Array, pairs: jax.Array,
                    interpret: Optional[bool] = None):
    return _is.edge_candidates(A, pairs, interpret=_auto_interpret(interpret))
