"""Pallas TPU kernel: l-clique *listing* inside dense bitset tiles.

Counting (:mod:`repro.kernels.clique_count`) collapses the last three DFS
levels into one closed-form triangle count; listing cannot collapse quite
as far, because the caller needs the member ids of every completed clique.
This kernel family keeps the same explicit-stack DFS (scalar core drives a
``lax.while_loop``, VPU does the (T, W) set math) but closes a branch as
soon as *two* levels remain: every edge (u, w) left in the candidate-induced
subgraph completes the current prefix, so the whole edge frontier is
scattered into a fixed-capacity per-tile output buffer in a single
vectorized (T, T) step (:func:`repro.kernels.common.emit_edges`) -- no
per-vertex scalar stepping through the deepest level.  The l <= 3 cases
never enter the loop at all: l == 3 scatters the whole (v, u, w) triangle
frontier of the tile in one vectorized step
(:func:`repro.kernels.common.emit_triangles`), which makes k = 5 listing a
single fused op per tile.  All emit index math is shared with the compiled
lax backend (:mod:`repro.kernels.lax_backend`), so the two backends fill
byte-identical buffers.

Per tile the kernel returns

* ``out (capacity, l) int32`` -- local vertex ids of the first ``capacity``
  cliques, in DFS (lexicographic local id) order;
* ``count () uint32``        -- the TRUE number of l-cliques found (keeps
  counting past capacity, so the host can size a retry or cross-check the
  counting kernel);
* ``overflow () uint32``     -- 1 iff ``count > capacity``.  The host never
  truncates: an overflowed tile is re-listed by the host bitset recursion
  (the spill path of :mod:`repro.core.listing`).

The emit buffer lives in the loop carry (a pure (capacity, l) value, like
the stack), so the DFS stays a single functional ``while_loop`` and the
only ref writes happen once at the end -- same discipline as the counting
kernel.  VMEM per program: A block + gt mask + stack + the buffer
(capacity * l * 4 bytes; the default cap ``listing.MAX_CAPACITY`` = 16384
rows bounds it at 16384 x 5 x 4 B = 320 KiB worst case for l = 5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    WORD,
    emit_edges,
    emit_frontier,
    emit_triangles,
    gt_masks_np,
    num_words,
    popcount,
)


def _kernel(
    A_ref, cand_ref, gt_ref, out_ref, cnt_ref, ovf_ref, *, l: int, T: int, capacity: int
):
    W = num_words(T)
    A = A_ref[0]  # (T, W)
    cand0 = cand_ref[0]  # (W,)
    gt = gt_ref[...]  # (T, W)
    buf0 = jnp.zeros((capacity, l), dtype=jnp.int32)
    count0 = jnp.uint32(0)
    zpfx = jnp.zeros((l,), dtype=jnp.int32)

    def finish(buf, count):
        out_ref[0] = buf
        cnt_ref[0] = count
        ovf_ref[0] = (count > jnp.uint32(capacity)).astype(jnp.uint32)

    # l <= 3: the whole tile is one vectorized frontier scatter -- no DFS.
    if l == 1:
        finish(
            *emit_frontier(buf0, count0, cand0, zpfx, l=l, T=T, capacity=capacity)
        )
        return
    if l == 2:
        finish(
            *emit_edges(buf0, count0, A, cand0, gt, zpfx, l=l, T=T, capacity=capacity)
        )
        return
    if l == 3:
        finish(
            *emit_triangles(
                buf0, count0, A, cand0, gt, zpfx, l=l, T=T, capacity=capacity
            )
        )
        return

    # stack[d] = candidate bitset at depth d; cursor[d] = next vertex to
    # try; prefix[d] = vertex chosen when descending from depth d.  Depth d
    # has l - d levels remaining; the edge-frontier emit fires at depth
    # l - 2 (two levels remaining).
    depth0 = jnp.int32(0)
    stack0 = jnp.zeros((l, W), dtype=jnp.uint32).at[0].set(cand0)
    cursor0 = jnp.zeros((l,), dtype=jnp.int32)
    prefix0 = jnp.zeros((l,), dtype=jnp.int32)

    def cond(state):
        return state[0] >= 0

    def body(state):
        depth, stack, cursor, prefix, buf, count = state
        cand = stack[depth]
        remaining = l - depth

        def emit(_):
            # two levels remain: every edge of the candidate-induced
            # subgraph completes the prefix -- one vectorized scatter
            b2, c2 = emit_edges(
                buf, count, A, cand, gt, prefix, l=l, T=T, capacity=capacity
            )
            return depth - 1, stack, cursor, prefix, b2, c2

        def step(_):
            v = cursor[depth]

            def pop(_):
                return depth - 1, stack, cursor, prefix, buf, count

            def advance(_):
                word = cand[v // WORD]
                bit = (word >> (v % WORD).astype(jnp.uint32)) & jnp.uint32(1)
                cur2 = cursor.at[depth].set(v + 1)

                def push(_):
                    sub = cand & A[v] & gt[v]
                    nsub = popcount(sub).sum().astype(jnp.int32)
                    ok = nsub >= remaining - 1

                    def do_push(_):
                        st = stack.at[depth + 1].set(sub)
                        cu = cur2.at[depth + 1].set(v + 1)
                        pf = prefix.at[depth].set(v)
                        return depth + 1, st, cu, pf, buf, count

                    return jax.lax.cond(
                        ok,
                        do_push,
                        lambda _: (depth, stack, cur2, prefix, buf, count),
                        None,
                    )

                return jax.lax.cond(
                    bit > 0,
                    push,
                    lambda _: (depth, stack, cur2, prefix, buf, count),
                    None,
                )

            return jax.lax.cond(v >= T, pop, advance, None)

        return jax.lax.cond(remaining == 2, emit, step, None)

    _, _, _, _, buf, count = jax.lax.while_loop(
        cond, body, (depth0, stack0, cursor0, prefix0, buf0, count0)
    )
    finish(buf, count)


@functools.partial(jax.jit, static_argnames=("l", "capacity", "interpret"))
def clique_list_tiles(
    A: jax.Array, cand: jax.Array, l: int, capacity: int, interpret: bool = True
):
    """List l-cliques per tile into fixed-capacity buffers.

    A: (B, T, W) uint32 packed adjacency, cand: (B, W) uint32.
    Returns (out (B, capacity, l) int32 local ids, count (B,) uint32 true
    per-tile totals, overflow (B,) uint32 flags).
    """
    if l < 1:
        raise ValueError("listing kernel requires l >= 1")
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    B, T, W = A.shape
    assert W == num_words(T) and cand.shape == (B, W)
    gt = jnp.asarray(gt_masks_np(T))
    kernel = functools.partial(_kernel, l=l, T=T, capacity=capacity)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, W), lambda b: (b, 0)),
            pl.BlockSpec((T, W), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity, l), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, capacity, l), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.uint32),
            jax.ShapeDtypeStruct((B,), jnp.uint32),
        ],
        interpret=interpret,
    )(A, cand, gt)
