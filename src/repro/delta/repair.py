"""Localized truss-order and tile-table repair after an edge batch.

The load-bearing facts (DESIGN.md section 13):

* **Any total edge order is correct.**  Exact-once attribution (paper
  Eq. 2) assigns each k-clique to the tile of its minimum-rank edge; the
  truss order pi_tau only controls tile-size *bounds* (Lemma 4.1).  A
  repaired order that merely approximates pi_tau near the batch is
  therefore exact, just possibly a little less tight.
* **Survivor order is preserved.**  Edges present before and after the
  batch keep their relative rank order; inserted edges receive fractional
  sort keys placed by a local support estimate.  Every rank comparison
  between two surviving edges -- which is all the untouched tiles ever
  consume -- is unchanged.
* **The touched set is closed over cliques.**  For each batch pair
  (u, v), taken against both the old and new graphs: the pair itself,
  every edge of a triangle containing it, and every edge with both
  endpoints in N(u) & N(v).  Any clique containing a batch pair consists
  entirely of such edges, so clique deltas live entirely in the
  retired-vs-replaced tiles (see :mod:`repro.delta.query`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core import pipeline
from ..core.graph import Graph, ragged_expand
from ..core.truss import (TrussDecomposition, edge_subset_supports)
from ..obs import trace

#: default churn threshold: when a batch touches more than this fraction
#: of the (new) edge set, local repair stops paying for itself -- the
#: spliced table approaches a full rebuild's size while the repaired
#: order drifts from pi_tau -- so repair_plan falls back to build_plan
#: and records the decision in Stats.plan_rebuilds
CHURN_THRESHOLD = 0.15

# pair-expansion budget for the common-neighborhood scan (caps peak
# index memory, mirroring pipeline._PAIR_BUDGET)
_PAIR_BUDGET = 4_000_000


@dataclasses.dataclass(frozen=True)
class RepairInfo:
    """Outcome record of one :func:`repair_plan` call.

    ``touched_old`` / ``touched_new`` are the sorted edge ids (in the old
    and new graphs respectively) whose tiles were retired / replaced --
    exactly the tile sets :func:`repro.delta.query.delta_cliques` runs the
    clique delta over.  ``rebuilt`` marks the churn-threshold (or
    unsupported-family) full-rebuild fallback.
    """

    rebuilt: bool
    churn: float
    n_insert: int
    n_delete: int
    touched_old: np.ndarray
    touched_new: np.ndarray
    repair_s: float


def touched_edge_ids(g: Graph, batch_keys: np.ndarray) -> np.ndarray:
    """Sorted ids of every edge of ``g`` whose tile a batch may change.

    ``batch_keys`` are canonical u*n+v keys of the inserted+deleted pairs
    (present in ``g`` or not).  Cost is bounded by the batch pairs'
    neighborhoods: one ragged expansion finds each pair's common
    neighbors, a second (budget-sliced) expansion probes the pairs inside
    each common neighborhood.
    """
    batch_keys = np.asarray(batch_keys, dtype=np.int64)
    if batch_keys.size == 0 or g.m == 0:
        return np.zeros(0, dtype=np.int64)
    n = np.int64(g.n)
    bu, bv = batch_keys // n, batch_keys % n
    ek = g.edge_keys()
    parts: List[np.ndarray] = []
    # (1) the batch pairs that are edges of g
    hit, p = pipeline._edge_lookup(ek, g.m, g.n, bu, bv)
    parts.append(p[hit])
    # common neighborhood of each batch pair: expand the smaller
    # endpoint's adjacency, keep vertices adjacent to the other endpoint
    deg = g.degrees()
    a = np.where(deg[bu] <= deg[bv], bu, bv)
    b = np.where(deg[bu] <= deg[bv], bv, bu)
    owner, pos = ragged_expand(deg[a])
    idx = g.indptr[a][owner] + pos
    w = g.indices[idx]
    common = g.has_edges(b[owner], w) & (w != b[owner])
    ow, cw = owner[common], w[common]
    if ow.size == 0:
        return np.unique(np.concatenate(parts))
    # (2) triangle edges (u, w) and (v, w), w in N(u) & N(v)
    parts.append(g.edge_ids(bu[ow], cw))
    parts.append(g.edge_ids(bv[ow], cw))
    # (3) edges with both endpoints inside one common neighborhood: the
    # batch pair flips an internal adjacency bit of their tiles
    counts = np.bincount(ow, minlength=batch_keys.size).astype(np.int64)
    starts = np.cumsum(counts) - counts
    quad = counts ** 2
    cum = np.cumsum(quad)
    npairs = batch_keys.size
    start = 0
    while start < npairs:
        stop = int(np.searchsorted(
            cum, (cum[start - 1] if start else 0) + _PAIR_BUDGET) + 1)
        stop = max(start + 1, min(stop, npairs))
        so = counts[start:stop]
        powner, ppos = ragged_expand(so * so)
        c_rep = so[powner]
        i = ppos // c_rep
        j = ppos % c_rep
        keep = i < j
        powner, i, j = powner[keep], i[keep], j[keep]
        base = starts[start:stop][powner]
        w1 = cw[base + i]
        w2 = cw[base + j]
        hit3 = g.has_edges(w1, w2)
        parts.append(g.edge_ids(w1[hit3], w2[hit3]))
        start = stop
    return np.unique(np.concatenate(parts))


def repair_truss(g_old: Graph, td_old: TrussDecomposition, g_new: Graph,
                 recompute: Optional[np.ndarray] = None
                 ) -> TrussDecomposition:
    """Survivor-order-preserving truss order for ``g_new``.

    Surviving edges keep their relative pi_tau order from ``td_old``;
    inserted edges get fractional sort keys placed where their locally
    recomputed support first fits the survivors' (non-decreasing)
    trussness profile, with canonical edge order as the deterministic
    tie-break.  The dense argsort of those keys is the repaired order.

    ``support0`` is patched exactly for ``recompute`` ids (the touched
    set) plus all inserted edges; ``trussness`` / ``peel_support`` /
    ``tau`` are *estimates* on a repaired decomposition -- they feed only
    the next repair's placement heuristic and diagnostics, never tile
    content (the table builders consume ``rank`` alone).
    """
    ok, nk = g_old.edge_keys(), g_new.edge_keys()
    m_new = g_new.m
    if m_new == 0:
        z = np.zeros(0, dtype=np.int64)
        return TrussDecomposition(z, z, z, z, z, 0)
    pos = np.searchsorted(ok, nk)
    pos = np.clip(pos, 0, max(ok.size - 1, 0))
    surv = (ok[pos] == nk) if ok.size else np.zeros(m_new, dtype=bool)
    old_id = pos[surv]
    sortkey = np.empty(m_new, dtype=np.float64)
    sortkey[surv] = td_old.rank[old_id].astype(np.float64)
    ins_ids = np.nonzero(~surv)[0]
    if ins_ids.size:
        sup = edge_subset_supports(g_new, ins_ids)
        surv_rank = td_old.rank[old_id]
        o = np.argsort(surv_rank)
        # trussness is a running max along pi_tau, so the survivor
        # subsequence stays non-decreasing -- searchsorted is well-defined
        tr_sorted = td_old.trussness[old_id][o]
        rank_sorted = surv_rank[o].astype(np.float64)
        if rank_sorted.size == 0:
            key_ins = np.zeros(ins_ids.size, dtype=np.float64)
        else:
            at = np.searchsorted(tr_sorted, sup, side="left")
            key_ins = np.where(
                at < rank_sorted.size,
                rank_sorted[np.minimum(at, rank_sorted.size - 1)] - 0.5,
                rank_sorted[-1] + 1.0)
        sortkey[ins_ids] = key_ins
    order = np.lexsort((np.arange(m_new, dtype=np.int64), sortkey))
    rank = np.empty(m_new, dtype=np.int64)
    rank[order] = np.arange(m_new, dtype=np.int64)
    # patch support0 locally; inherit the rest from the survivors
    support0 = np.zeros(m_new, dtype=np.int64)
    support0[surv] = td_old.support0[old_id]
    redo = ins_ids if recompute is None else np.unique(
        np.concatenate([np.asarray(recompute, dtype=np.int64), ins_ids]))
    if redo.size:
        support0[redo] = edge_subset_supports(g_new, redo)
    trussness = np.zeros(m_new, dtype=np.int64)
    trussness[surv] = td_old.trussness[old_id]
    if ins_ids.size:
        trussness[ins_ids] = support0[ins_ids]
    # re-impose the running-max invariant along the repaired order (the
    # placement heuristic of the *next* repair searchsorts over this)
    trussness[order] = np.maximum.accumulate(trussness[order])
    peel = np.minimum(trussness, support0)
    tau = int(peel.max(initial=0))
    return TrussDecomposition(order=order, rank=rank, support0=support0,
                              peel_support=peel, trussness=trussness,
                              tau=tau)


def splice_truss_table(old_table: pipeline.TileTable, g_old: Graph,
                       g_new: Graph, td_new: TrussDecomposition,
                       touched_old: np.ndarray, touched_new: np.ndarray
                       ) -> pipeline.TileTable:
    """Retire touched tiles, rebuild their replacements, splice in place.

    Kept rows (tiles of untouched edges) are byte-identical member lists
    from ``old_table``; replacement rows come from the localized
    :func:`~repro.core.pipeline._build_truss_table` subset build.  The
    merged table is re-sorted to the canonical tile order (descending
    owner rank), so the result is array-identical to a full table build
    under ``td_new`` -- the splice is pure bookkeeping, never semantics.
    """
    ok, nk = g_old.edge_keys(), g_new.edge_keys()
    keep = ~np.isin(old_table.edge_id, touched_old)
    kept_rows = np.nonzero(keep)[0]
    # untouched tiles belong to surviving edges by construction; their
    # ids shift because the canonical edge list re-sorts
    kept_eid_new = np.searchsorted(nk, ok[old_table.edge_id[kept_rows]])
    ksz = (old_table.offsets[kept_rows + 1]
           - old_table.offsets[kept_rows]).astype(np.int64)
    kowner, kpos = ragged_expand(ksz)
    kverts = old_table.verts[old_table.offsets[kept_rows][kowner] + kpos]
    sub = pipeline._build_truss_table(
        g_new, td_new, eids=np.asarray(touched_new, dtype=np.int64))
    edge_id = np.concatenate([kept_eid_new, sub.edge_id])
    anchors = np.concatenate(
        [old_table.anchors[kept_rows], sub.anchors], axis=0)
    sizes = np.concatenate([ksz, np.diff(sub.offsets)])
    verts_all = np.concatenate([kverts, sub.verts])
    # per-tile segment starts inside verts_all: kept segments are packed
    # contiguously into kverts, sub segments follow at a kverts offset
    kept_starts = (np.cumsum(ksz) - ksz) if ksz.size else ksz
    seg_starts = np.concatenate(
        [kept_starts, kverts.size + sub.offsets[:-1].astype(np.int64)])
    # canonical tile order: descending owner rank (ranks are unique)
    order = np.argsort(-td_new.rank[edge_id], kind="stable")
    sz_o = sizes[order]
    offsets = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(sz_o)]).astype(np.int64)
    nowner, npos = ragged_expand(sz_o)
    verts = verts_all[seg_starts[order][nowner] + npos] \
        if verts_all.size else verts_all
    eid_o = edge_id[order]
    return pipeline.TileTable(
        "truss", eid_o, anchors[order], offsets, verts,
        td_new.rank[eid_o], nk, td_new.rank)


def repair_plan(plan: pipeline.PipelinePlan, g_new: Graph,
                order: str = "hybrid", *,
                churn_threshold: float = CHURN_THRESHOLD,
                stats=None) -> "tuple[pipeline.PipelinePlan, RepairInfo]":
    """Repair ``plan`` (built on its old graph) into a plan for ``g_new``.

    Returns ``(new_plan, info)``.  The decision -- local repair vs full
    rebuild -- is recorded in ``stats`` (``plan_repairs`` /
    ``plan_rebuilds`` / ``plan_repair_s`` / ``delta_touched_edges``; a
    rebuild's cost lands in ``plan_build_s`` as usual).  Falls back to a
    rebuild when the batch touches more than ``churn_threshold`` of the
    new edge set, for the color family (its global greedy coloring has no
    local repair), or when the plan lacks a built truss decomposition.
    The repaired plan's counts and listing rows are byte-identical to a
    from-scratch plan of ``g_new`` (the mutation differential fuzz family
    asserts exactly this).
    """
    if order not in ("truss", "hybrid", "color"):
        raise ValueError(f"unknown edge-tile mode: {order}")
    g_old = plan.g
    if g_new.n != g_old.n:
        raise ValueError("apply_edge_batch preserves the vertex set; "
                         f"got n={g_old.n} -> {g_new.n}")
    t0 = time.perf_counter()
    ok, nk = g_old.edge_keys(), g_new.edge_keys()
    ins_keys = np.setdiff1d(nk, ok, assume_unique=True)
    del_keys = np.setdiff1d(ok, nk, assume_unique=True)
    batch = np.union1d(ins_keys, del_keys)
    touched_old = touched_edge_ids(g_old, batch)
    touched_new = touched_edge_ids(g_new, batch)
    # close the two sets over surviving edges: a survivor flagged on one
    # side must be retired AND rebuilt, never one without the other --
    # e.g. two deleted edges sharing a neighborhood can make w a common
    # neighbor in g_old only, so (u, w) lands in touched_old alone; an
    # unmatched retire would silently drop that tile from the splice
    # (and the mirror case would duplicate one).  The survivor maps are
    # bijective, so a single symmetric pass reaches the fixed point.
    so = np.isin(ok[touched_old], nk, assume_unique=True)
    sn = np.isin(nk[touched_new], ok, assume_unique=True)
    touched_old, touched_new = (
        np.union1d(touched_old,
                   np.searchsorted(ok, nk[touched_new][sn])),
        np.union1d(touched_new,
                   np.searchsorted(nk, ok[touched_old][so])),
    )
    churn = touched_new.size / max(g_new.m, g_old.m, 1)
    family = "color" if order == "color" else "truss"
    repairable = (family == "truss" and plan._td is not None
                  and family in plan._tables and churn <= churn_threshold)
    if not repairable:
        new_plan = pipeline.build_plan(g_new, order=order)
        dt = time.perf_counter() - t0
        if stats is not None:
            stats.plan_rebuilds += 1
            stats.plan_build_s += dt
        trace.instant("delta/rebuild", churn=round(churn, 4),
                      touched=int(touched_new.size), order=order)
        return new_plan, RepairInfo(
            True, churn, int(ins_keys.size), int(del_keys.size),
            touched_old, touched_new, dt)
    td_new = repair_truss(g_old, plan._td, g_new, recompute=touched_new)
    table = splice_truss_table(plan._tables[family], g_old, g_new, td_new,
                               touched_old, touched_new)
    new_plan = pipeline.PipelinePlan(
        g=g_new, _td=td_new, _tables={family: table})
    dt = time.perf_counter() - t0
    if stats is not None:
        stats.plan_repairs += 1
        stats.plan_repair_s += dt
        stats.delta_touched_edges += int(touched_new.size)
    trace.instant("delta/repair", churn=round(churn, 4),
                  touched=int(touched_new.size),
                  tiles=int(table.ntiles), ms=round(dt * 1e3, 3))
    return new_plan, RepairInfo(
        False, churn, int(ins_keys.size), int(del_keys.size),
        touched_old, touched_new, dt)
