"""Incrementally-maintained plan index for dynamic graphs (DESIGN.md 13).

EBBkC's preprocessing -- truss order + per-edge membership tables -- is
the amortized O(delta*m) cost a :class:`~repro.core.pipeline.PipelinePlan`
spreads over repeated queries.  This package keeps that amortization
alive under edge churn: :func:`repair_plan` repairs a cached plan after a
:func:`~repro.core.graph.apply_edge_batch` mutation by re-deriving only
the tiles the batch could have changed (cost bounded by the touched
neighborhood, with a full-rebuild fallback past a churn threshold), and
:class:`PlanIndex` wraps that into a versioned graph lineage with
per-batch clique deltas (:func:`delta_cliques`) computed from the
retired-vs-replaced tile sets via the paper's exact-once attribution.

Soundness in one line: Eq. 2 attributes every k-clique to exactly one
edge tile for ANY total edge order, so a repair that preserves surviving
edges' relative rank order and rebuilds exactly the content-changed
tiles produces identical counts and listings to a from-scratch plan.
"""
from .repair import (CHURN_THRESHOLD, RepairInfo, repair_plan,
                     repair_truss, splice_truss_table, touched_edge_ids)
from .query import DeltaResult, delta_cliques, rows_diff, rows_union
from .index import PlanIndex

__all__ = [
    "CHURN_THRESHOLD", "RepairInfo", "repair_plan", "repair_truss",
    "splice_truss_table", "touched_edge_ids", "DeltaResult",
    "delta_cliques", "rows_diff", "rows_union", "PlanIndex",
]
