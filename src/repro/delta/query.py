"""Clique deltas from retired-vs-replaced tile sets (DESIGN.md 13).

Untouched tiles produce bit-identical cliques before and after a batch
(their member lists, internal adjacency, and relative ranks are all
preserved by the repair), and every clique containing a batch pair lives
entirely inside touched tiles.  So the clique delta of a batch is exactly

    lost   = cliques(retired tiles of the old plan)  \\ cliques(replaced)
    gained = cliques(replaced tiles of the new plan) \\ cliques(retired)

Both subsets run through the *standard* listing machinery -- a subset
:class:`~repro.core.pipeline.TileTable` wrapped in a shim plan is
indistinguishable from a full plan to ``iter_tiles``/``stream_batches``
-- so delta queries inherit every engine path (host recursion, packed
device batches, spill handling) without new kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core import ebbkc, pipeline
from ..core.graph import ragged_expand
from .repair import RepairInfo


def rows_sorted(rows: np.ndarray) -> np.ndarray:
    """Canonical presentation: rows (already sorted within) lexsorted."""
    if rows.shape[0] == 0:
        return rows
    return rows[np.lexsort(rows.T[::-1])]


def _membership(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Boolean mask over ``a``'s rows: present in ``b`` (rows canonical)."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return np.zeros(a.shape[0], dtype=bool)
    both = np.concatenate([a, b], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    inv_a, inv_b = inv[: a.shape[0]], inv[a.shape[0]:]
    hit = np.zeros(int(inv.max()) + 1, dtype=bool)
    hit[inv_b] = True
    return hit[inv_a]


def rows_diff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Set difference a \\ b over clique rows (each row vertex-sorted)."""
    return a[~_membership(a, b)]


def rows_union(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Set union over clique rows, deduplicated, canonically sorted."""
    if a.shape[0] == 0:
        return rows_sorted(b.copy())
    if b.shape[0] == 0:
        return rows_sorted(a.copy())
    return np.unique(np.concatenate([a, b], axis=0), axis=0)


def subset_table(table: pipeline.TileTable, eids: np.ndarray
                 ) -> pipeline.TileTable:
    """A TileTable holding only the tiles owned by edges in ``eids``.

    Row order, member order, thresholds, and the shared ``ekeys`` /
    ``erank`` arrays are preserved, so packing a subset tile is
    byte-identical to packing the same tile out of the full table.
    """
    keep = np.isin(table.edge_id, np.asarray(eids, dtype=np.int64))
    rows = np.nonzero(keep)[0]
    sz = (table.offsets[rows + 1] - table.offsets[rows]).astype(np.int64)
    owner, pos = ragged_expand(sz)
    verts = table.verts[table.offsets[rows][owner] + pos] \
        if rows.size else table.verts[:0]
    offsets = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(sz)]).astype(np.int64)
    kw = {}
    for opt in ("member_colors", "ncolors", "rule1"):
        val = getattr(table, opt)
        if val is not None:
            kw[opt] = val[table.offsets[rows][owner] + pos] \
                if opt == "member_colors" else val[rows]
    return pipeline.TileTable(
        table.family, table.edge_id[rows], table.anchors[rows], offsets,
        verts, table.thresh[rows], table.ekeys, table.erank, **kw)


def subset_plan(plan: pipeline.PipelinePlan, order: str,
                eids: np.ndarray) -> pipeline.PipelinePlan:
    """Shim plan restricted to the tiles of ``eids`` (standard machinery).

    The table is pre-populated, so consumers never trigger a lazy
    rebuild; the graph rides along for adjacency probes at pack time.
    """
    family = "color" if order == "color" else "truss"
    return pipeline.PipelinePlan(
        g=plan.g, _td=plan._td, _colors=plan._colors,
        _tables={family: subset_table(plan.table(order), eids)})


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """Cliques gained/lost by one batch (or a composed version range)."""

    k: int
    gained: np.ndarray  # (ng, k) int64, rows vertex-sorted, lexsorted
    lost: np.ndarray    # (nl, k) int64

    @property
    def net(self) -> int:
        """Net clique-count change (gained minus lost)."""
        return int(self.gained.shape[0] - self.lost.shape[0])


def delta_cliques(old_plan: pipeline.PipelinePlan,
                  new_plan: pipeline.PipelinePlan, info: RepairInfo,
                  k: int, order: str = "hybrid", *,
                  backend: str = "host",
                  engine_kwargs: Optional[dict] = None) -> DeltaResult:
    """Exact per-batch clique delta from the touched tile sets.

    Lists the retired tiles against the old plan and the replaced tiles
    against the new plan (standard engines; ``backend``/``engine_kwargs``
    forward to :func:`repro.core.ebbkc.list_cliques`), then set-differences
    the two row sets.  After a churn-fallback rebuild there is no touched
    subset to exploit for the *new* side's attribution (ranks moved
    arbitrarily), so both sides list in full -- still exact, just not
    localized.
    """
    if k < 3:
        raise ValueError("delta queries require k >= 3")
    if info.rebuilt:
        rows_old, _ = ebbkc.list_cliques(
            old_plan.g, k, order=order, plan=old_plan, backend=backend,
            engine_kwargs=engine_kwargs)
        rows_new, _ = ebbkc.list_cliques(
            new_plan.g, k, order=order, plan=new_plan, backend=backend,
            engine_kwargs=engine_kwargs)
    else:
        sp_old = subset_plan(old_plan, order, info.touched_old)
        sp_new = subset_plan(new_plan, order, info.touched_new)
        rows_old, _ = ebbkc.list_cliques(
            sp_old.g, k, order=order, plan=sp_old, backend=backend,
            engine_kwargs=engine_kwargs)
        rows_new, _ = ebbkc.list_cliques(
            sp_new.g, k, order=order, plan=sp_new, backend=backend,
            engine_kwargs=engine_kwargs)
    gained = rows_sorted(rows_diff(rows_new, rows_old))
    lost = rows_sorted(rows_diff(rows_old, rows_new))
    return DeltaResult(k=k, gained=gained, lost=lost)


def delta_net_count(old_plan: pipeline.PipelinePlan,
                    new_plan: pipeline.PipelinePlan, info: RepairInfo,
                    k: int, order: str = "hybrid", *,
                    backend: str = "host",
                    engine_kwargs: Optional[dict] = None
                    ) -> Tuple[int, int, int]:
    """(count_retired, count_replaced, net) via the counting engines.

    The cheap consistency probe paired with :func:`delta_cliques`:
    ``net == replaced - retired`` must equal
    ``gained - lost`` of the listing-based delta, and serves as the
    device-friendly path when only the net change is needed.
    """
    if k < 3:
        raise ValueError("delta queries require k >= 3")
    if info.rebuilt:
        c_old = ebbkc.count(old_plan.g, k, order=order, plan=old_plan,
                            backend=backend,
                            engine_kwargs=engine_kwargs).count
        c_new = ebbkc.count(new_plan.g, k, order=order, plan=new_plan,
                            backend=backend,
                            engine_kwargs=engine_kwargs).count
    else:
        sp_old = subset_plan(old_plan, order, info.touched_old)
        sp_new = subset_plan(new_plan, order, info.touched_new)
        c_old = ebbkc.count(sp_old.g, k, order=order, plan=sp_old,
                            backend=backend,
                            engine_kwargs=engine_kwargs).count
        c_new = ebbkc.count(sp_new.g, k, order=order, plan=sp_new,
                            backend=backend,
                            engine_kwargs=engine_kwargs).count
    return int(c_old), int(c_new), int(c_new - c_old)
