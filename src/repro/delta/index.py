"""PlanIndex: a versioned, incrementally-maintained plan over one graph.

The stateful front of :mod:`repro.delta`: holds the current graph
snapshot, its (repaired or rebuilt) plan, a monotonically increasing
version number, and a bounded lineage of recent batches.  Each
:meth:`PlanIndex.apply_batch` runs :func:`~repro.delta.repair.repair_plan`,
publishes the new plan into the process-wide keyed plan cache (so a
serving tier's next ``cached_plan`` lookup on the mutated graph is a
warm hit, never an O(delta*m) rebuild), optionally persists it with a
version-lineage metadata record, and retains the old/new plan pair so
clique deltas against any retained version remain answerable
(:meth:`PlanIndex.delta` composes per-batch gains/losses with exact set
algebra).
"""
from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

from ..core import pipeline
from ..core.engine_np import Stats
from ..core.graph import Graph, apply_edge_batch
from .query import DeltaResult, delta_cliques, rows_diff, rows_sorted, \
    rows_union
from .repair import CHURN_THRESHOLD, RepairInfo, repair_plan


@dataclasses.dataclass
class _BatchRecord:
    """One applied batch: the plans on either side plus the repair info."""

    version: int                      # version this batch produced
    old_plan: pipeline.PipelinePlan
    new_plan: pipeline.PipelinePlan
    info: RepairInfo
    deltas: Dict[int, DeltaResult] = dataclasses.field(default_factory=dict)

    def delta(self, k: int, order: str) -> DeltaResult:
        d = self.deltas.get(k)
        if d is None:
            d = delta_cliques(self.old_plan, self.new_plan, self.info, k,
                              order=order)
            self.deltas[k] = d
        return d


class PlanIndex:
    """Incrementally-maintained plan + delta lineage for a dynamic graph.

    Typical use::

        idx = PlanIndex(g)                       # version 0, plan built
        v1 = idx.apply_batch(insert=[(0, 9)])    # local repair (or rebuild)
        d = idx.delta(k=4, since=0)              # cliques gained/lost
        ebbkc.count(idx.graph, 4, plan=idx.plan) # warm exact queries

    ``history`` bounds how many batch records (old/new plan pairs) are
    retained; deltas spanning further back raise.  ``stats`` (default: an
    internal :class:`~repro.core.engine_np.Stats`) accumulates the
    repair/rebuild decisions and timings.  Not thread-safe by itself --
    the serving tier serializes updates per graph entry.
    """

    def __init__(self, g: Graph, order: str = "hybrid", *,
                 churn_threshold: float = CHURN_THRESHOLD,
                 cache_dir: Optional[str] = None, history: int = 16,
                 stats: Optional[Stats] = None) -> None:
        if order not in ("truss", "hybrid", "color"):
            raise ValueError(f"unknown edge-tile mode: {order}")
        self.order = order
        self.churn_threshold = float(churn_threshold)
        self.cache_dir = cache_dir
        self.stats = stats if stats is not None else Stats()
        self.graph = g
        self.version = 0
        self.plan = pipeline.cached_plan(
            g, order, cache_dir=cache_dir, stats=self.stats)
        self._records: Deque[_BatchRecord] = deque(maxlen=max(1, history))

    @property
    def plan_key(self) -> str:
        """Content-addressed key of the current plan (cache identity)."""
        return pipeline.plan_key(self.graph, self.order)

    def apply_batch(self, insert=None, delete=None) -> int:
        """Apply one edge batch; returns the new version number.

        Mutates the index to the new graph snapshot and repaired plan,
        publishes the plan into the keyed in-process cache under the new
        graph's key, and (when ``cache_dir`` is set) persists it with a
        lineage metadata record ``{version, parent_key, repaired, churn,
        inserted, deleted}`` readable via
        :func:`repro.checkpoint.store.read_metadata`.
        """
        parent_key = self.plan_key
        g_new = apply_edge_batch(self.graph, insert=insert, delete=delete)
        new_plan, info = repair_plan(
            self.plan, g_new, self.order,
            churn_threshold=self.churn_threshold, stats=self.stats)
        self._records.append(_BatchRecord(
            self.version + 1, self.plan, new_plan, info))
        self.graph = g_new
        self.plan = new_plan
        self.version += 1
        key = pipeline.plan_key(g_new, self.order)
        pipeline._plan_cache_insert(key, new_plan)
        if self.cache_dir is not None:
            pipeline.save_plan(
                new_plan, os.path.join(self.cache_dir, key),
                lineage={"version": self.version, "parent_key": parent_key,
                         "repaired": not info.rebuilt,
                         "churn": round(info.churn, 6),
                         "inserted": info.n_insert,
                         "deleted": info.n_delete})
        return self.version

    def oldest_version(self) -> int:
        """Oldest version delta queries can still reach back to."""
        if not self._records:
            return self.version
        return self._records[0].version - 1

    def delta(self, k: int, since: int) -> DeltaResult:
        """Cliques gained/lost between version ``since`` and now.

        Composes the retained per-batch deltas with exact set algebra
        (a clique gained in one batch and lost in a later one cancels),
        so the result equals a from-scratch diff of the two snapshots.
        Raises when ``since`` is ahead of the index or behind the
        retained history window.
        """
        if since > self.version or since < 0:
            raise ValueError(
                f"since={since} outside [0, {self.version}]")
        if since < self.oldest_version():
            raise ValueError(
                f"delta history starts at version {self.oldest_version()}"
                f" (got since={since}; raise history=)")
        gained = np.zeros((0, k), dtype=np.int64)
        lost = np.zeros((0, k), dtype=np.int64)
        for rec in self._records:
            if rec.version <= since:
                continue
            d = rec.delta(k, self.order)
            # S_since is fixed; step the running diff through this batch
            gained, lost = (
                rows_union(rows_diff(gained, d.lost),
                           rows_diff(d.gained, lost)),
                rows_union(rows_diff(lost, d.gained),
                           rows_diff(d.lost, gained)),
            )
        return DeltaResult(k=k, gained=rows_sorted(gained),
                           lost=rows_sorted(lost))

    def gained_since(self, k: int, since: int,
                     vertex: Optional[int] = None) -> np.ndarray:
        """Rows of cliques gained since ``since`` (the subscription read).

        ``vertex`` restricts to cliques containing that vertex -- the
        same semantics as the serving tier's ``vertex_filter``.
        """
        rows = self.delta(k, since).gained
        if vertex is not None and rows.shape[0]:
            rows = rows[(rows == vertex).any(axis=1)]
        return rows
