"""Geometry + backend search: live microbenchmarks behind the tuning cache.

Two searches live here:

* :func:`microbench_backend` -- the lax-vs-pallas winner for one kernel
  signature, on a tiny synthetic half-dense batch (compile excluded via a
  warmup call).  ``backend="autotune"`` (:mod:`repro.kernels.ops`) calls
  this only when no persisted record answers first.
* :func:`tune_geometry` -- budgeted coordinate descent over the shape
  knobs the pipeline keys executables on: tile-width rounding policy
  (pow2 bins vs multiples of 32 -- fewer distinct ``(T, W)`` signatures vs
  tighter packing), ``batch_size``, emit-capacity rounding policy and cap,
  and the pack-producer's ``pack_workers``/``prefetch``.  The descent
  starts from the hardcoded defaults and only moves off them on a > 2%
  measured win, so the tuned geometry never loses to the defaults by more
  than measurement noise; the result is persisted as one geometry
  :class:`~repro.tune.records.TuningRecord` that
  :func:`resolve_geometry` serves back as the pipeline's defaults.

Everything here is *explicitly invoked* (``benchmarks/run.py --tune``, or
a first-ever ``backend="autotune"`` call); a query that only *reads* tuned
defaults never pays for a search.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import cache as _cache
from . import records as _rec
from .records import TuningRecord
from ..obs import trace

#: keep in sync with repro.core.listing.MAX_CAPACITY (not imported: the
#: listing module consumes this package's geometry defaults)
DEFAULT_MAX_CAPACITY = 1 << 14

#: hardcoded bin ladders per tile-width rounding policy; every entry is a
#: multiple of 32 (the uint32 word layout) and <= the largest kernel tile
_BIN_POLICIES: Dict[str, Tuple[int, ...]] = {
    "pow2": (32, 64, 128, 256),
    "mult32": (32, 64, 96, 128, 160, 192, 224, 256),
}


def bins_for(policy: str) -> Tuple[int, ...]:
    """Tile-size bin ladder for a T-rounding policy.

    ``pow2`` (the historical default) keeps the number of distinct
    ``(T, W)`` kernel signatures -- and hence XLA executables -- at four;
    ``mult32`` packs tiles tighter (less padded compute per tile) at the
    cost of up to eight signatures.  Which wins is a hardware question;
    that is why it is a tuned knob.
    """
    try:
        return _BIN_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown T-rounding policy {policy!r}; "
            f"expected one of {sorted(_BIN_POLICIES)}") from None


@dataclasses.dataclass
class Geometry:
    """The shape knobs one query runs with.  Defaults == pre-tuner behavior."""

    t_policy: str = "pow2"
    batch_size: int = 256
    cap_policy: str = "pow2"           # listing emit-capacity rounding
    max_capacity: int = DEFAULT_MAX_CAPACITY
    pack_workers: Optional[int] = None  # None = auto pool size
    prefetch: Optional[int] = None      # None = 2x workers
    #: explicit caller-supplied bin ladder; beats t_policy when set (the
    #: ladder need not match any named policy, e.g. bins=(32,) in tests)
    bins_override: Optional[Tuple[int, ...]] = None

    @property
    def bins(self) -> Tuple[int, ...]:
        if self.bins_override is not None:
            return self.bins_override
        return bins_for(self.t_policy)


def geometry_from_record(rec: TuningRecord) -> Geometry:
    """Geometry encoded in a record; unknown/missing fields keep defaults."""
    g = Geometry()
    for f in dataclasses.fields(Geometry):
        if f.name in rec.data and rec.data[f.name] is not None:
            setattr(g, f.name, rec.data[f.name])
    return g


def resolve_geometry(mode: str, l: int, *,
                     batch_size: Optional[int] = None,
                     bins: Optional[Sequence[int]] = None,
                     cap_policy: Optional[str] = None,
                     max_capacity: Optional[int] = None,
                     pack_workers: Optional[int] = None,
                     prefetch: Optional[int] = None) -> Geometry:
    """Concrete geometry for one query under the precedence ladder.

    Explicit argument > persisted/in-process tuning record > hardcoded
    default -- per knob, so a caller can pin ``batch_size`` while still
    inheriting a tuned capacity policy.  Never searches; with no record
    and no arguments this returns exactly the historical defaults.
    """
    with trace.span("tune/resolve", mode=mode, l=l) as _sp:
        rec = _cache.get(_rec.geometry_key(mode, l))
        _sp.set(hit=rec is not None)
    if rec is not None:
        # answered from a tuning record; an absent record notes nothing
        # (an untuned run is not a cache miss)
        _cache.note_event(lookup=True)
        trace.instant("tune/cache_hit", source="geometry", mode=mode, l=l)
    g = geometry_from_record(rec) if rec is not None else Geometry()
    if batch_size is not None:
        g.batch_size = int(batch_size)
    if bins is not None:
        # an explicit bin ladder always wins, even one that matches no
        # named policy (bins=(32,) forces the oversize-spill path); when
        # it does match a policy, record that too so t_policy stays
        # consistent with what actually runs
        tb = tuple(int(b) for b in bins)
        g.bins_override = tb
        for name, ladder in _BIN_POLICIES.items():
            if ladder == tuple(sorted(tb)):
                g.t_policy = name
                g.bins_override = None  # the policy already encodes it
                break
    if cap_policy is not None:
        g.cap_policy = cap_policy
    if max_capacity is not None:
        g.max_capacity = int(max_capacity)
    if pack_workers is not None:
        g.pack_workers = pack_workers
    if prefetch is not None:
        g.prefetch = prefetch
    return g


# ---------------------------------------------------------------------------
# backend microbenchmark (the live fallback behind backend="autotune")
# ---------------------------------------------------------------------------


def microbench_backend(mode: str, l: int, T: int,
                       capacity: Optional[int] = None,
                       trials: int = 2) -> Tuple[str, Dict[str, float]]:
    """Fastest of lax vs pallas for one kernel signature.

    Runs each candidate on a tiny synthetic half-dense batch (compile
    excluded via a warmup call) at the *requested* capacity regime --
    the emit buffer rides the DFS carry, so a winner measured at
    capacity 64 says nothing about capacity 16384.  Returns
    ``(winner, {backend: seconds/call})``.
    """
    import jax

    from ..core.bitops import pack_bits
    from ..kernels import ops as kops

    rng = np.random.default_rng(0)
    B = 4
    dense = rng.random((B, T, T)) < 0.5
    dense = np.triu(dense, 1)
    dense = dense | dense.transpose(0, 2, 1)
    A = pack_bits(dense)
    cand = pack_bits(np.ones((B, T), dtype=bool))
    cap = min(int(capacity), DEFAULT_MAX_CAPACITY) if capacity else 64
    times: Dict[str, float] = {}
    for b in ("lax", "pallas"):
        def run():
            if mode == "list":
                return kops.list_tiles(A, cand, l, capacity=cap, backend=b)
            return kops.count_tiles(A, cand, l, backend=b)
        jax.block_until_ready(run())  # warmup: compile outside the timing
        t0 = time.perf_counter()
        for _ in range(trials):
            jax.block_until_ready(run())
        times[b] = (time.perf_counter() - t0) / trials
    winner = min(times, key=times.get)
    return winner, times


# ---------------------------------------------------------------------------
# geometry coordinate descent
# ---------------------------------------------------------------------------

#: a candidate must beat the incumbent by this factor to displace it --
#: hysteresis that keeps the defaults in place under measurement noise
#: (and makes "tuned never loses to default" hold by construction)
MIN_GAIN = 1.02


def _default_graph(scale: int = 9):
    from ..data import rmat_graph

    return rmat_graph(scale, 6, seed=7)


def _eval_geometry(g, plan, mode: str, k: int, geom: Geometry,
                   backend: Optional[str]) -> float:
    """Items/s of one geometry candidate on the synthetic sweep workload.

    Two runs; the first pays whatever compile the candidate's new shapes
    cost (persisted to the compilation cache), the second is the
    measurement.  Plan prebuilt, plan cache bypassed: only the knobs under
    test vary.
    """
    from ..core import engine_jax, listing

    kw = dict(batch_size=geom.batch_size, bins=geom.bins,
              pack_workers=geom.pack_workers, prefetch=geom.prefetch,
              backend=backend, plan_cache=False)
    best = float("inf")
    items = 1
    for i in range(2):
        t0 = time.perf_counter()
        if mode == "list":
            sink = listing.CallbackSink(lambda rows: None)
            res = listing.stream_cliques(
                plan, k, sink, cap_policy=geom.cap_policy,
                max_capacity=geom.max_capacity, **kw)
            items = max(1, res.stats.emitted_cliques)
        else:
            r = engine_jax.count(plan.g, k, plan=plan, **kw)
            items = max(1, r.tiles)
        dt = time.perf_counter() - t0
        if i:  # first run is the compile warmer
            best = min(best, dt)
    return items / max(best, 1e-9)


def tune_geometry(mode: str, l: int, *, budget_s: float = 20.0,
                  graph=None, backend: Optional[str] = None,
                  persist: bool = True) -> TuningRecord:
    """Budgeted coordinate descent over the pipeline shape knobs.

    Starts from the hardcoded defaults, sweeps one knob at a time on a
    synthetic workload (rmat scale 9 unless ``graph`` is given), adopts a
    candidate only on a > :data:`MIN_GAIN` measured win, and stops when
    ``budget_s`` of search time is spent or a full pass makes no change.
    Emits (and, with ``persist``, writes through the tuning cache) one
    geometry record that :func:`resolve_geometry` then serves as the
    defaults for every later query of this (device kind, mode, l).
    """
    from ..core import pipeline

    g = graph if graph is not None else _default_graph()
    k = l + 2
    plan = pipeline.build_plan(g, order="hybrid")
    t_start = time.perf_counter()
    geom = Geometry()
    base_tp = _eval_geometry(g, plan, mode, k, geom, backend)
    best_tp = base_tp
    knobs: List[Tuple[str, list]] = [
        ("t_policy", ["mult32"]),
        ("batch_size", [64, 128, 512]),
        ("pack_workers", [0, 2]),
    ]
    if mode == "list":
        knobs.append(("cap_policy", ["mult64"]))
        knobs.append(("max_capacity", [1 << 12]))
    evals = 1
    improved = True
    while improved and time.perf_counter() - t_start < budget_s:
        improved = False
        for name, alts in knobs:
            for val in alts:
                if time.perf_counter() - t_start >= budget_s:
                    break
                if getattr(geom, name) == val:
                    continue
                cand = dataclasses.replace(geom, **{name: val})
                tp = _eval_geometry(g, plan, mode, k, cand, backend)
                evals += 1
                if tp > best_tp * MIN_GAIN:
                    geom, best_tp, improved = cand, tp, True
    search_s = time.perf_counter() - t_start
    rec = TuningRecord(
        "geometry", _rec.device_kind(), _rec.jax_version(), mode, int(l),
        data={**dataclasses.asdict(geom),
              "searched": True, "search_s": search_s, "evals": evals,
              "throughput": best_tp, "baseline_throughput": base_tp})
    if persist:
        _cache.put(rec)
    return rec
