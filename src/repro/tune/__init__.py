"""Persistent geometry/backend autotuner (DESIGN.md section 9).

Light imports only: :mod:`~repro.tune.records` (schema + keys) and
:mod:`~repro.tune.cache` (persisted record store + JAX compilation-cache
wiring).  The search machinery (:mod:`~repro.tune.search`) pulls in the
engines and is imported lazily by its callers.
"""
from .cache import (ENV_TUNE_CACHE, active_dir, clear_memory, configure,
                    consume_events, enable_compilation_cache, get, note_event,
                    put)
from .records import (FORMAT, TuningRecord, backend_key, capacity_bucket,
                      device_kind, geometry_key, jax_version, key_digest)

__all__ = [
    "ENV_TUNE_CACHE", "FORMAT", "TuningRecord",
    "active_dir", "backend_key", "capacity_bucket", "clear_memory",
    "configure", "consume_events", "device_kind", "enable_compilation_cache",
    "geometry_key", "get", "jax_version", "key_digest", "note_event", "put",
]
