"""Tuning-record schema: what the persistent autotuner knows, versioned.

A :class:`TuningRecord` is one persisted fact about the hardware this
process runs on.  Two kinds exist (DESIGN.md section 9):

* ``kind="backend"`` -- the winner of the lax-vs-pallas microbenchmark for
  one kernel signature ``(mode, l, T, W, capacity bucket)`` on one device
  kind.  Consulted by ``backend="autotune"`` (:mod:`repro.kernels.ops`)
  before any live microbenchmark runs, so a warm process never re-measures.
* ``kind="geometry"`` -- the shape knobs the pipeline keys executables on:
  tile-width rounding policy, batch size, emit-capacity rounding and cap,
  pack workers / prefetch depth.  Emitted by the coordinate-descent search
  (:mod:`repro.tune.search`) and read back as the *defaults* of
  ``stream_batches`` / ``stream_cliques`` / ``engine_jax.count`` whenever
  the caller leaves those knobs ``None``.

Records are keyed per (device kind, jax version, mode, l[, T, W, capacity
bucket]) -- a cache warmed on one device kind or capacity regime can never
leak a stale winner into another (the PR-6 key fix).  ``FORMAT`` is bumped
on any schema change; readers treat a mismatched or unreadable record as
absent and fall back to a live measurement.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional

import jax

#: serialized tuning-record layout version; bump on any schema change so a
#: stale on-disk record is re-measured instead of misread
FORMAT = 1


def device_kind() -> str:
    """Stable identifier of the accelerator family this process targets.

    ``device_kind`` (e.g. "TPU v5e") where the runtime provides it, else
    the platform name ("cpu", "gpu").  Part of every tuning-record key: a
    winner measured on one device kind is never served to another.
    """
    try:
        d = jax.devices()[0]
        return str(getattr(d, "device_kind", None) or d.platform)
    except Exception:  # pragma: no cover - no backend at all
        return "unknown"


def jax_version() -> str:
    return jax.__version__


def capacity_bucket(capacity: Optional[int]) -> int:
    """Fold a listing capacity into its pow2 regime (-1 = counting mode).

    Capacities inside one bucket share kernel executables and memory
    behavior, so they share one tuning record; capacities in different
    buckets (say 64 vs 16384 rows) can have different winners -- the buffer
    rides the DFS ``while_loop`` carry, taxing every iteration.
    """
    if capacity is None:
        return -1
    return max(0, int(capacity) - 1).bit_length()


@dataclasses.dataclass
class TuningRecord:
    """One persisted tuning fact (see module docstring for the two kinds)."""

    kind: str                 # "backend" | "geometry"
    device_kind: str
    jax_version: str
    mode: str                 # "count" | "list"
    l: int
    T: int = 0                # backend records: tile width (0 = n/a)
    W: int = 0                # backend records: word count (0 = n/a)
    cap_bucket: int = -1      # backend records: capacity regime (-1 = count)
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def key(self) -> str:
        """Stable record key; the on-disk store hashes it into a dirname."""
        return (f"v{FORMAT}:{self.kind}:{self.device_kind}:"
                f"{self.jax_version}:{self.mode}:l{self.l}:T{self.T}:"
                f"W{self.W}:c{self.cap_bucket}")

    def to_meta(self) -> Dict[str, Any]:
        return {"format": FORMAT, "record": dataclasses.asdict(self)}

    @staticmethod
    def from_meta(meta: Dict[str, Any]) -> Optional["TuningRecord"]:
        """Parse store metadata; None on any format/shape mismatch."""
        if not isinstance(meta, dict) or meta.get("format") != FORMAT:
            return None
        rec = meta.get("record")
        if not isinstance(rec, dict):
            return None
        try:
            return TuningRecord(**rec)
        except TypeError:
            return None


def backend_key(mode: str, l: int, T: int,
                capacity: Optional[int] = None) -> str:
    """Key of the backend-winner record for one kernel signature."""
    return TuningRecord(
        "backend", device_kind(), jax_version(), mode, int(l), T=int(T),
        W=int(T) // 32, cap_bucket=capacity_bucket(capacity)).key()


def geometry_key(mode: str, l: int) -> str:
    """Key of the geometry record the pipeline reads its defaults from."""
    return TuningRecord(
        "geometry", device_kind(), jax_version(), mode, int(l)).key()


def key_digest(key: str) -> str:
    """Filesystem-safe digest of a record key (store subdirectory name)."""
    return hashlib.sha256(key.encode()).hexdigest()[:24]
