"""Persistent tuning cache + JAX compilation cache wiring.

One directory (the ``--tune-cache DIR`` knob, or the ``REPRO_TUNE_CACHE``
environment variable) holds everything a warm process needs to skip every
one-time cost:

* ``<dir>/records/<digest>/`` -- one :class:`~repro.tune.records.TuningRecord`
  per kernel-signature/geometry key, written atomically through the
  format-versioned :mod:`repro.checkpoint.store` writer (tmp dir +
  ``os.replace`` + ``COMMITTED`` marker).  Concurrent writers of the same
  key race benignly: ``os.replace`` is atomic, the last committed record
  wins, and a reader never observes a partial write.
* ``<dir>/xla/`` -- JAX's persistent compilation cache, enabled the first
  time a tune-cache directory is configured, so every XLA executable the
  kernels key on ``(mode, l, T, W, capacity, B)`` is compiled once per
  *machine*, not once per process.

Read path: an in-process dict in front of the on-disk store.  A corrupt,
stale-format, or foreign record reads as absent -- the caller falls back
to a live microbenchmark and overwrites it.  No record is ever trusted
across a :data:`repro.tune.records.FORMAT` bump, a jax upgrade, or a
device-kind change (all three are part of the key).
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

from . import records as _rec
from ..obs import metrics as _metrics
from .records import TuningRecord

#: environment knob: equivalent to calling :func:`configure` at startup
ENV_TUNE_CACHE = "REPRO_TUNE_CACHE"

_LOCK = threading.Lock()
_DIR: Optional[str] = None
_ENV_CHECKED = False
_XLA_ENABLED = False
_MEM: Dict[str, TuningRecord] = {}


def configure(directory: Optional[str], *, xla_cache: bool = True) -> None:
    """Activate a persistent tune-cache directory for this process.

    Enables the JAX persistent compilation cache under ``<dir>/xla`` (once
    per process; ``xla_cache=False`` skips it, for tests that must not
    mutate global jax config).  ``None`` deactivates the on-disk layer
    (the in-process dict survives).
    """
    global _DIR, _ENV_CHECKED
    with _LOCK:
        _ENV_CHECKED = True  # explicit configure beats the env knob
        if directory is None:
            _DIR = None
            return
        directory = os.path.abspath(directory)
        os.makedirs(os.path.join(directory, "records"), exist_ok=True)
        _DIR = directory
    if xla_cache:
        enable_compilation_cache(os.path.join(directory, "xla"))


def active_dir() -> Optional[str]:
    """The configured cache directory, consulting ``REPRO_TUNE_CACHE`` once."""
    global _ENV_CHECKED
    with _LOCK:
        if _DIR is not None or _ENV_CHECKED:
            return _DIR
        _ENV_CHECKED = True
    env = os.environ.get(ENV_TUNE_CACHE)
    if env:
        configure(env)
    return _DIR


def enable_compilation_cache(directory: str) -> bool:
    """Point JAX's persistent compilation cache at ``directory``.

    Thresholds are dropped to zero so even the small fixed-shape clique
    kernels persist (default jax only caches compiles > 1s).  Idempotent;
    returns False (and leaves config untouched) on jax builds without the
    persistent cache.  Safe to call after backend initialization -- the
    cache is consulted per compile, not at startup.
    """
    global _XLA_ENABLED
    if _XLA_ENABLED:
        return True
    import jax

    try:
        os.makedirs(directory, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", directory)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:  # pragma: no cover - jax without the persistent cache
        return False
    _XLA_ENABLED = True
    return True


def clear_memory() -> None:
    """Drop the in-process record layer (tests; disk records survive)."""
    with _LOCK:
        _MEM.clear()


# ---------------------------------------------------------------------------
# tuning-event accounting (drained into Stats.tune_s / tune_cache_hit)
# ---------------------------------------------------------------------------

_TUNE_S = 0.0
_TUNE_LOOKUPS = 0
_TUNE_MISSES = 0


def note_event(seconds: float = 0.0, lookup: bool = False,
               miss: bool = False) -> None:
    """Accrue one tuning event (same pattern as ops' compile accumulator).

    ``lookup`` marks a record consultation that *answered* from a cache
    layer; ``miss`` marks one that had to fall back to a live measurement
    (whose wall-clock lands in ``seconds``).  Geometry reads that find no
    record note nothing -- an untuned run is not a cache miss.
    """
    global _TUNE_S, _TUNE_LOOKUPS, _TUNE_MISSES
    with _LOCK:
        _TUNE_S += seconds
        if lookup:
            _TUNE_LOOKUPS += 1
        if miss:
            _TUNE_MISSES += 1
    if seconds:
        _metrics.REGISTRY.counter(
            "repro_tune_seconds_total",
            help="wall seconds spent in live tuning measurements",
        ).inc(seconds)
    if lookup:
        _metrics.REGISTRY.counter(
            "repro_tune_lookups_total",
            help="tuning-record consultations answered from a cache layer",
        ).inc()
    if miss:
        _metrics.REGISTRY.counter(
            "repro_tune_misses_total",
            help="tuning lookups that fell back to a live measurement",
        ).inc()


def consume_events() -> tuple:
    """Drain the accumulator -> ``(tune_s, lookups, misses)``.

    Engines call this where they drain ``ops.consume_compile_s`` and derive
    ``Stats.tune_cache_hit = lookups > 0 and misses == 0``.
    """
    global _TUNE_S, _TUNE_LOOKUPS, _TUNE_MISSES
    with _LOCK:
        out = (_TUNE_S, _TUNE_LOOKUPS, _TUNE_MISSES)
        _TUNE_S, _TUNE_LOOKUPS, _TUNE_MISSES = 0.0, 0, 0
    return out


def _record_dir(base: str, key: str) -> str:
    return os.path.join(base, "records", _rec.key_digest(key))


def get(key: str) -> Optional[TuningRecord]:
    """Record for ``key``: in-process layer, then the on-disk store.

    Any unreadable / stale-format / wrong-key record reads as None -- the
    caller re-measures and overwrites.  Never raises.
    """
    with _LOCK:
        got = _MEM.get(key)
    if got is not None:
        return got
    base = active_dir()
    if base is None:
        return None
    from ..checkpoint import store

    from ..resilience import inject

    try:
        inject.fire("tune.read")
        ckpt = store.restore_checkpoint(_record_dir(base, key),
                                        _corrupt_site="tune.read")
    except Exception:
        return None  # corrupt on-disk record: fall back to live measurement
    if ckpt is None:
        return None
    rec = TuningRecord.from_meta(ckpt.get("metadata"))
    if rec is None or rec.key() != key:
        return None
    with _LOCK:
        _MEM[key] = rec
    return rec


def put(rec: TuningRecord) -> None:
    """Persist one record (atomic, best-effort) and cache it in-process.

    Uses the checkpoint store's commit protocol; a concurrent writer of
    the same key is resolved by ``os.replace`` (last committed wins).
    Disk errors are swallowed -- the tuning cache is an accelerator, never
    a correctness dependency.
    """
    import numpy as np

    key = rec.key()
    with _LOCK:
        _MEM[key] = rec
    base = active_dir()
    if base is None:
        return
    from ..checkpoint import store

    try:
        store.save_checkpoint(
            _record_dir(base, key), 0,
            {"format": np.int64(_rec.FORMAT)}, metadata=rec.to_meta())
    except OSError:
        pass  # lost a same-key race or a full disk; next process re-measures
