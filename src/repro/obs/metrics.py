"""Process-wide metrics registry: counters, gauges, pow2-bucket histograms.

This unifies the ad-hoc ``Stats`` / ``ServeStats`` accounting behind one
API.  The dataclasses remain the per-run/per-request *snapshot views*;
this module is the cumulative, scrapeable view.  Two publication styles
are supported:

* **Direct instruments** -- hot-path code grabs a counter once and bumps
  it (``REGISTRY.counter("repro_batches_total").inc()``).
* **Snapshot publication** -- ``observe_stats(stats)`` folds a finished
  stats dataclass into the registry, classifying each field via the
  dataclass's ``_METRIC_KINDS`` table (the same table that drives
  ``Stats.merge``), so new fields cannot silently diverge between the
  merge path and the metrics path.

Naming convention (see DESIGN.md section 11): ``repro_<area>_<what>``,
snake_case, with Prometheus unit/``_total`` suffixes.  Exposition lives in
:mod:`repro.obs.export`.  Standard library only; no repro imports.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "REGISTRY",
    "get_registry",
    "pow2_edges",
    "observe_stats",
    "publish_totals",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing counter (rendered as TYPE counter)."""

    kind = "counter"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    def set_total(self, v: float) -> None:
        """Publish an externally-maintained monotonic total (scrape-time)."""
        with self._lock:
            self._value = max(self._value, float(v))

    @property
    def value(self) -> float:
        """Current accumulated total."""
        with self._lock:
            return self._value


class Gauge:
    """Last-written value (rendered as TYPE gauge)."""

    kind = "gauge"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self._value = float(v)

    def set_max(self, v: float) -> None:
        """Raise the gauge to ``v`` if larger (peak-style gauges)."""
        with self._lock:
            self._value = max(self._value, float(v))

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` to the gauge."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current gauge value."""
        with self._lock:
            return self._value


def pow2_edges(lo_exp: int, hi_exp: int) -> List[float]:
    """Power-of-two bucket upper bounds: ``2**lo_exp .. 2**hi_exp``."""
    if hi_exp < lo_exp:
        raise ValueError("hi_exp must be >= lo_exp")
    return [float(2.0**e) for e in range(lo_exp, hi_exp + 1)]


# Default histogram edges: ~1 microsecond to 64 seconds, pow2 steps.
_DEFAULT_EDGES = pow2_edges(-20, 6)


class Histogram:
    """Cumulative histogram over power-of-two buckets."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        labels: LabelKey = (),
        edges: Optional[Iterable[float]] = None,
    ):
        self.name = name
        self.labels = labels
        self.edges = sorted(set(float(e) for e in (edges or _DEFAULT_EDGES)))
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.edges) + 1)  # last = +Inf
        self._sum = 0.0
        self._n = 0

    def observe(self, v: float) -> None:
        """Record one observation."""
        v = float(v)
        with self._lock:
            self._sum += v
            self._n += 1
            for i, edge in enumerate(self.edges):
                if v <= edge:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    def snapshot(self) -> Tuple[List[int], float, int]:
        """(per-bucket counts, sum, count) under the lock."""
        with self._lock:
            return list(self._counts), self._sum, self._n

    @property
    def value(self) -> float:
        """Observation count (for quick assertions in tests)."""
        with self._lock:
            return float(self._n)


class Registry:
    """Thread-safe get-or-create store of metric instruments."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelKey], Any] = {}
        self._help: Dict[str, str] = {}
        self._collectors: List[Callable[[], None]] = []

    def _get(self, cls, name: str, help: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels or {}))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name} already registered as {m.kind}"
                )
            if help:
                self._help.setdefault(name, help)
            return m

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        """Get or create a counter for ``name`` + ``labels``."""
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        """Get or create a gauge for ``name`` + ``labels``."""
        return self._get(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        edges: Optional[Iterable[float]] = None,
        **labels: str,
    ) -> Histogram:
        """Get or create a pow2-bucket histogram for ``name`` + ``labels``."""
        return self._get(Histogram, name, help, labels, edges=edges)

    def add_collector(self, fn: Callable[[], None]) -> None:
        """Register a scrape-time callback that refreshes instruments."""
        with self._lock:
            self._collectors.append(fn)

    def remove_collector(self, fn: Callable[[], None]) -> None:
        """Remove a previously registered scrape-time callback."""
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    def collect(self) -> List[Any]:
        """Run collectors, then return instruments grouped by family name."""
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()
        with self._lock:
            return sorted(
                self._metrics.values(), key=lambda m: (m.name, m.labels)
            )

    def help_text(self, name: str) -> str:
        """HELP string registered for a metric family (may be empty)."""
        with self._lock:
            return self._help.get(name, "")

    def reset(self) -> None:
        """Drop all instruments and collectors (test isolation)."""
        with self._lock:
            self._metrics.clear()
            self._help.clear()
            self._collectors.clear()


REGISTRY = Registry()


def get_registry() -> Registry:
    """The process-wide default registry."""
    return REGISTRY


def observe_stats(
    stats: Any,
    prefix: str = "repro_engine",
    registry: Optional[Registry] = None,
) -> None:
    """Fold a finished stats dataclass into the registry.

    Field handling follows the dataclass's ``_METRIC_KINDS`` table
    (``sum`` -> counter add, ``max`` -> peak gauge, ``flag`` -> hit
    counter, ``dict`` -> per-key labelled counter, ``list`` -> histogram
    observations, ``info`` -> skipped).  Unclassified numeric fields are
    treated as ``sum`` so new accounting shows up by default.
    """
    reg = registry or REGISTRY
    kinds = getattr(type(stats), "_METRIC_KINDS", {})
    for f in dataclasses.fields(stats):
        val = getattr(stats, f.name)
        kind = kinds.get(f.name)
        if kind is None:
            kind = "sum" if isinstance(val, (int, float)) else "info"
        name = f"{prefix}_{f.name}"
        if kind == "sum":
            if isinstance(val, bool):
                val = int(val)
            if val:
                reg.counter(name + "_total").inc(val)
            else:
                reg.counter(name + "_total")
        elif kind == "max":
            reg.gauge(name, help="peak value").set_max(val)
        elif kind == "flag":
            reg.counter(name + "s_total").inc(1 if val else 0)
        elif kind == "dict":
            for k, v in (val or {}).items():
                reg.counter(name + "_total", key=str(k)).inc(v)
        elif kind == "list":
            h = reg.histogram(name)
            for v in val or ():
                h.observe(v)
        # "info" fields (e.g. backend strings) are identity, not metrics.


def publish_totals(
    stats: Any,
    prefix: str,
    registry: Optional[Registry] = None,
) -> None:
    """Publish a *cumulative* stats object as current totals (scrape-time).

    Unlike :func:`observe_stats` (which adds a finished per-run snapshot
    into the registry once), this sets counters to the stats object's
    absolute values -- the right shape for long-lived accumulators like a
    service's ``ServeStats``/engine ``Stats`` that already hold lifetime
    totals.  Counters only move forward (``set_total`` keeps the max), so
    concurrent in-place resets never violate counter monotonicity.
    """
    reg = registry or REGISTRY
    kinds = getattr(type(stats), "_METRIC_KINDS", {})
    for f in dataclasses.fields(stats):
        val = getattr(stats, f.name)
        kind = kinds.get(f.name)
        if kind is None:
            kind = "sum" if isinstance(val, (int, float)) else "info"
        name = f"{prefix}_{f.name}"
        if kind == "sum":
            reg.counter(name + "_total").set_total(
                int(val) if isinstance(val, bool) else val
            )
        elif kind in ("max", "mean"):
            reg.gauge(name).set_max(val)
        elif kind == "flag":
            reg.gauge(name).set(1 if val else 0)
        elif kind == "dict":
            for k, v in (val or {}).items():
                reg.counter(name + "_total", key=str(k)).set_total(v)
        elif kind == "list":
            reg.gauge(name + "_count").set(len(val or ()))
        # "info" fields are identity, not metrics.
