"""Low-overhead structured span tracer with Chrome/Perfetto export.

The tracer records *spans* -- named intervals with attributes -- into a
bounded in-process ring buffer.  Spans nest per-thread (a well-formed tree
per thread, tracked via a thread-local stack), carry a monotonic
``perf_counter_ns`` clock, and export as Chrome ``trace_event`` JSON that
Perfetto (https://ui.perfetto.dev) loads directly.

Design constraints (see DESIGN.md section 11):

* **Disabled is (almost) free.**  ``span(...)`` with tracing off returns a
  shared no-op context manager without allocating; the only cost is one
  global flag check plus the caller's keyword packing.  The overhead budget
  (<= 1% on the bench-smoke workload) is asserted by
  ``tests/test_obs.py``.
* **Thread-safe.**  Finished events append to a lock-protected
  ``collections.deque(maxlen=...)``; per-thread nesting state lives in a
  ``threading.local`` so concurrent producers never contend on the stack.
* **Cross-thread request trees.**  A request's lifecycle hops threads
  (client -> scheduler -> decode worker), so it cannot be a sync span.
  ``async_begin`` / ``async_instant`` / ``async_end`` emit Chrome async
  events (``ph`` = ``b``/``n``/``e``) keyed by an explicit id (the serving
  tier uses the ticket id), which Perfetto renders as one track per id.

Only the standard library is used; this module must not import jax or any
``repro`` sibling (it sits below everything else in the import DAG).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "span",
    "instant",
    "complete",
    "async_begin",
    "async_instant",
    "async_end",
    "enabled",
    "configure",
    "reset",
    "events",
    "dropped",
    "span_records",
    "chrome_trace",
    "export",
    "validate_chrome_trace",
    "stage_durations",
]

# Category assigned to synchronous spans in the chrome export.
_CAT_SYNC = "repro"
# Category assigned to async (per-request) events.  Chrome async events are
# matched on (cat, id), so this must be stable.
_CAT_ASYNC = "request"

_DEFAULT_CAPACITY = 262_144


class _NoopSpan:
    """Shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False

    def set(self, **args: Any) -> None:
        """Ignore attribute updates (tracing disabled)."""


_NOOP = _NoopSpan()


class _Span:
    """An open span; records itself into the tracer on ``__exit__``."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_parent", "_tid")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **args: Any) -> None:
        """Attach or update span attributes while the span is open."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        tls = self._tracer._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        self._parent = stack[-1].name if stack else None
        self._tid = threading.get_ident()
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: object) -> bool:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        stack = tr._tls.stack
        # Tolerate exits out of order (shouldn't happen with `with`): pop
        # back to this span rather than corrupting the stack.
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        tr._record(
            {
                "ph": "X",
                "name": self.name,
                "ts": self._t0,
                "dur": t1 - self._t0,
                "tid": self._tid,
                "parent": self._parent,
                "args": self.args,
            }
        )
        return False


class Tracer:
    """Ring-buffered span recorder.  One process-wide instance is the norm."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._tls = threading.local()
        self._dropped = 0

    # -- recording ---------------------------------------------------------

    def _record(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(ev)

    def span(self, name: str, **args: Any) -> _Span:
        """Open a nested span; use as ``with tracer.span("pack", T=64):``."""
        return _Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        """Record a zero-duration point event on the current thread."""
        self._record(
            {
                "ph": "i",
                "name": name,
                "ts": time.perf_counter_ns(),
                "tid": threading.get_ident(),
                "parent": self._current_name(),
                "args": args,
            }
        )

    def complete(self, name: str, t0_ns: int, dur_ns: int, **args: Any) -> None:
        """Record a span retroactively from explicit start/duration.

        Used where the interval is only known after the fact (e.g. how long
        a listing payload sat parked in the reorder buffer).
        """
        self._record(
            {
                "ph": "X",
                "name": name,
                "ts": int(t0_ns),
                "dur": max(0, int(dur_ns)),
                "tid": threading.get_ident(),
                "parent": None,
                "args": args,
            }
        )

    def _current_name(self) -> Optional[str]:
        stack = getattr(self._tls, "stack", None)
        return stack[-1].name if stack else None

    # -- async (cross-thread) events --------------------------------------

    def async_begin(self, name: str, id: Any, **args: Any) -> None:
        """Open an async track keyed by ``id`` (e.g. a serve ticket id)."""
        self._async(name, "b", id, args)

    def async_instant(self, name: str, id: Any, **args: Any) -> None:
        """Record a point event on the async track keyed by ``id``."""
        self._async(name, "n", id, args)

    def async_end(self, name: str, id: Any, **args: Any) -> None:
        """Close the async track keyed by ``id``."""
        self._async(name, "e", id, args)

    def _async(self, name: str, ph: str, id: Any, args: Dict[str, Any]) -> None:
        self._record(
            {
                "ph": ph,
                "name": name,
                "ts": time.perf_counter_ns(),
                "tid": threading.get_ident(),
                "id": str(id),
                "args": args,
            }
        )

    # -- inspection / export -----------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot the raw ring buffer (oldest first)."""
        with self._lock:
            return list(self._events)

    def span_records(self) -> List[Tuple[str, Optional[str]]]:
        """(name, parent_name) pairs for finished sync spans, oldest first.

        This is the structural view the determinism tests compare: it is
        independent of wall-clock timing but captures the nesting tree.
        """
        return [
            (ev["name"], ev.get("parent"))
            for ev in self.events()
            if ev["ph"] == "X"
        ]

    @property
    def dropped(self) -> int:
        """Number of events evicted from the ring buffer since reset."""
        with self._lock:
            return self._dropped

    def reset(self) -> None:
        """Drop all recorded events (keeps the enabled flag as-is)."""
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def chrome_trace(self) -> Dict[str, Any]:
        """Render the buffer as a Chrome ``trace_event`` JSON object."""
        pid = os.getpid()
        out: List[Dict[str, Any]] = []
        tids: Dict[int, int] = {}
        tid_names: Dict[int, str] = {}
        for th in threading.enumerate():
            tid_names[th.ident] = th.name
        for ev in self.events():
            tid = tids.setdefault(ev["tid"], len(tids) + 1)
            rec: Dict[str, Any] = {
                "name": ev["name"],
                "ph": ev["ph"],
                "ts": ev["ts"] / 1000.0,  # ns -> us
                "pid": pid,
                "tid": tid,
                "args": {k: _jsonable(v) for k, v in ev["args"].items()},
            }
            if ev["ph"] == "X":
                rec["cat"] = _CAT_SYNC
                rec["dur"] = ev["dur"] / 1000.0
            elif ev["ph"] in ("b", "n", "e"):
                rec["cat"] = _CAT_ASYNC
                rec["id"] = ev["id"]
            else:  # instant
                rec["cat"] = _CAT_SYNC
                rec["s"] = "t"
            out.append(rec)
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": small,
                "args": {"name": tid_names.get(raw, f"thread-{small}")},
            }
            for raw, small in sorted(tids.items(), key=lambda kv: kv[1])
        ]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        """Write the Chrome trace JSON to ``path``."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)


def _jsonable(v: Any) -> Any:
    """Coerce span attribute values to JSON-safe scalars."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:
        return int(v)
    except (TypeError, ValueError):
        pass
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


# -- module-level API (the form the hot path uses) ---------------------------

_ENABLED = False
_TRACER = Tracer()


def enabled() -> bool:
    """Whether span recording is currently on."""
    return _ENABLED


def configure(enabled: bool = True, capacity: Optional[int] = None) -> Tracer:
    """Turn tracing on/off; optionally resize (and clear) the ring buffer."""
    global _ENABLED, _TRACER
    if capacity is not None and capacity != _TRACER._events.maxlen:
        _TRACER = Tracer(capacity=capacity)
    _ENABLED = bool(enabled)
    return _TRACER


def reset() -> None:
    """Clear recorded events on the process tracer."""
    _TRACER.reset()


def span(name: str, **args: Any):
    """Open a span on the process tracer; no-op when tracing is disabled."""
    if not _ENABLED:
        return _NOOP
    return _TRACER.span(name, **args)


def instant(name: str, **args: Any) -> None:
    """Record a point event; no-op when tracing is disabled."""
    if _ENABLED:
        _TRACER.instant(name, **args)


def complete(name: str, t0_ns: int, dur_ns: int, **args: Any) -> None:
    """Record a retroactive span; no-op when tracing is disabled."""
    if _ENABLED:
        _TRACER.complete(name, t0_ns, dur_ns, **args)


def async_begin(name: str, id: Any, **args: Any) -> None:
    """Open an async per-id track; no-op when tracing is disabled."""
    if _ENABLED:
        _TRACER.async_begin(name, id, **args)


def async_instant(name: str, id: Any, **args: Any) -> None:
    """Point event on an async per-id track; no-op when disabled."""
    if _ENABLED:
        _TRACER.async_instant(name, id, **args)


def async_end(name: str, id: Any, **args: Any) -> None:
    """Close an async per-id track; no-op when tracing is disabled."""
    if _ENABLED:
        _TRACER.async_end(name, id, **args)


def events() -> List[Dict[str, Any]]:
    """Snapshot raw events from the process tracer."""
    return _TRACER.events()


def dropped() -> int:
    """Events lost to the ring-buffer capacity bound so far."""
    return _TRACER.dropped


def span_records() -> List[Tuple[str, Optional[str]]]:
    """Structural (name, parent) pairs for finished sync spans."""
    return _TRACER.span_records()


def chrome_trace() -> Dict[str, Any]:
    """Chrome ``trace_event`` JSON object for the process tracer."""
    return _TRACER.chrome_trace()


def export(path: str) -> None:
    """Write the process tracer's Chrome trace JSON to ``path``."""
    _TRACER.export(path)


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Shape-check a trace document; returns a list of problems (empty = ok).

    Checks the subset of the ``trace_event`` spec Perfetto requires to load
    the file: a ``traceEvents`` list, per-event ``name``/``ph``/``ts``/
    ``pid``/``tid``, ``dur`` on complete events, and matched ``b``/``e``
    pairs per async id.
    """
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    open_async: Dict[Tuple[str, str], int] = {}
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph == "M":
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i} missing {key}")
        if ph == "X" and "dur" not in ev:
            problems.append(f"complete event {i} missing dur")
        if ph in ("b", "n", "e"):
            if "id" not in ev or "cat" not in ev:
                problems.append(f"async event {i} missing id/cat")
                continue
            k = (ev["cat"], ev["id"])
            if ph == "b":
                open_async[k] = open_async.get(k, 0) + 1
            elif ph == "e":
                open_async[k] = open_async.get(k, 0) - 1
                if open_async[k] < 0:
                    problems.append(f"async end without begin for id {k}")
    for k, n in open_async.items():
        if n > 0:
            problems.append(f"async begin without end for id {k}")
    return problems


def stage_durations(
    doc: Dict[str, Any], prefixes: Iterable[str] = ()
) -> Dict[str, float]:
    """Sum complete-event durations (seconds) by name, from a trace doc.

    With ``prefixes``, names are bucketed under the first matching prefix
    (e.g. ``device/stage`` and ``device/harvest`` both land in ``device``).
    """
    out: Dict[str, float] = {}
    pref = tuple(prefixes)
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        name = ev["name"]
        for p in pref:
            if name == p or name.startswith(p + "/"):
                name = p
                break
        out[name] = out.get(name, 0.0) + ev.get("dur", 0.0) / 1e6
    return out
