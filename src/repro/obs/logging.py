"""Logging setup for the ``repro`` stack.

Everything clique-side historically either printed or stayed silent (only
``runtime/train_loop.py`` created a logger).  This module gives the whole
tree one idempotent entry point: loggers live under the ``"repro"`` root,
``setup_logging`` attaches a single stream handler to it, and the CLIs
expose ``--log-level`` wired here.
"""

from __future__ import annotations

import logging
import sys
from typing import Optional, Union

__all__ = ["setup_logging", "get_logger", "LEVELS"]

LEVELS = ("debug", "info", "warning", "error", "critical")

_FORMAT = "%(asctime)s %(levelname)-7s %(name)s: %(message)s"
_HANDLER_FLAG = "_repro_obs_handler"


def setup_logging(
    level: Union[str, int] = "warning", stream=None
) -> logging.Logger:
    """Configure the ``repro`` root logger; safe to call repeatedly.

    Re-invocation updates the level but never stacks handlers, so CLIs and
    tests can call it freely.  Returns the root ``repro`` logger.
    """
    if isinstance(level, str):
        name = level.strip().lower()
        if name not in LEVELS:
            raise ValueError(f"unknown log level {level!r}; pick from {LEVELS}")
        level = getattr(logging, name.upper())
    root = logging.getLogger("repro")
    root.setLevel(level)
    handler = None
    for h in root.handlers:
        if getattr(h, _HANDLER_FLAG, False):
            handler = h
            break
    if handler is None:
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT))
        setattr(handler, _HANDLER_FLAG, True)
        root.addHandler(handler)
    elif stream is not None:
        handler.stream = stream
    root.propagate = False
    return root


def get_logger(name: Optional[str] = None) -> logging.Logger:
    """Get a logger under the ``repro`` namespace (``repro.<name>``)."""
    if not name:
        return logging.getLogger("repro")
    if name.startswith("repro.") or name == "repro":
        return logging.getLogger(name)
    return logging.getLogger(f"repro.{name}")
