"""Unified observability layer: tracing, metrics, logging, profiling.

``repro.obs`` sits at the bottom of the import DAG (stdlib only; jax is
imported lazily inside :mod:`repro.obs.profile`), so every other tier --
``core``, ``runtime``, ``serve``, ``tune``, the launchers and benchmarks
-- can instrument itself without new dependencies or cycles.

Quickstart::

    from repro.obs import trace, metrics

    trace.configure(enabled=True)
    with trace.span("pack", T=64):
        ...
    trace.export("trace.json")          # open in https://ui.perfetto.dev

    metrics.REGISTRY.counter("repro_batches_total").inc()

See DESIGN.md section 11 for the span taxonomy, metric naming convention
and overhead budget.
"""

from . import export, logging, metrics, profile, trace
from .logging import get_logger, setup_logging
from .metrics import REGISTRY, get_registry

__all__ = [
    "trace",
    "metrics",
    "export",
    "profile",
    "logging",
    "setup_logging",
    "get_logger",
    "REGISTRY",
    "get_registry",
]
