"""Prometheus text-format exposition for :mod:`repro.obs.metrics`.

``render_prometheus`` turns a :class:`~repro.obs.metrics.Registry` into
exposition text (version 0.0.4); ``MetricsServer`` serves it at
``/metrics`` from a background thread using only the standard library.
The server is off by default everywhere -- it is opted into via
``CliqueService(metrics_port=...)`` or the ``--metrics-port`` CLI flags.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.request import urlopen

from .metrics import REGISTRY, Counter, Gauge, Histogram, Registry

__all__ = ["render_prometheus", "MetricsServer", "scrape"]


def _fmt_labels(labels, extra=()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


def render_prometheus(registry: Optional[Registry] = None) -> str:
    """Render the registry in Prometheus text exposition format."""
    reg = registry or REGISTRY
    lines = []
    seen_family = set()

    def _family(name: str, kind: str) -> None:
        if name in seen_family:
            return
        seen_family.add(name)
        help_text = reg.help_text(name)
        if help_text:
            lines.append(f"# HELP {name} {_escape(help_text)}")
        lines.append(f"# TYPE {name} {kind}")

    for m in reg.collect():
        if isinstance(m, Counter):
            _family(m.name, "counter")
            lines.append(
                f"{m.name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}"
            )
        elif isinstance(m, Gauge):
            _family(m.name, "gauge")
            lines.append(
                f"{m.name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}"
            )
        elif isinstance(m, Histogram):
            _family(m.name, "histogram")
            counts, total, n = m.snapshot()
            cum = 0
            for edge, c in zip(m.edges + [float("inf")], counts):
                cum += c
                le = _fmt_labels(m.labels, [("le", _fmt_value(edge))])
                lines.append(f"{m.name}_bucket{le} {cum}")
            lab = _fmt_labels(m.labels)
            lines.append(f"{m.name}_sum{lab} {_fmt_value(total)}")
            lines.append(f"{m.name}_count{lab} {n}")
    return "\n".join(lines) + "\n"


class _Handler(BaseHTTPRequestHandler):
    registry: Registry = REGISTRY

    def do_GET(self):  # noqa: N802 (http.server API)
        """Serve /metrics (exposition text); 404 elsewhere."""
        if self.path.split("?")[0] not in ("/metrics", "/"):
            self.send_error(404)
            return
        try:
            body = render_prometheus(self.registry).encode()
        except Exception as exc:  # defensive: a collector may throw
            self.send_error(500, str(exc))
            return
        self.send_response(200)
        self.send_header(
            "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
        )
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):  # noqa: D102 (silence per-request stderr)
        pass


class MetricsServer:
    """Background /metrics HTTP server (stdlib ``ThreadingHTTPServer``).

    ``port=0`` binds an ephemeral port; read it back via :attr:`port` /
    :attr:`address`.  ``close()`` shuts the listener down and joins the
    serving thread.
    """

    def __init__(
        self,
        port: int = 0,
        host: str = "127.0.0.1",
        registry: Optional[Registry] = None,
    ):
        handler = type("Handler", (_Handler,), {"registry": registry or REGISTRY})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-metrics",
            daemon=True,
        )
        self._thread.start()

    @property
    def port(self) -> int:
        """The bound TCP port."""
        return self._httpd.server_address[1]

    @property
    def address(self) -> str:
        """``http://host:port`` for the running server."""
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        """Stop serving and release the socket."""
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()


def scrape(address: str, timeout: float = 5.0) -> str:
    """Fetch ``/metrics`` from a running server and return the text."""
    url = address.rstrip("/") + "/metrics"
    with urlopen(url, timeout=timeout) as resp:
        return resp.read().decode()
