"""Profiling hooks: jax.profiler capture + per-kernel-signature attribution.

Two facilities:

* :func:`profile_span` -- a context manager that opens a tracer span and,
  when given an output directory, additionally captures a ``jax.profiler``
  trace scoped to that span (viewable in Perfetto/TensorBoard).  JAX is
  imported lazily so this module stays importable without it.
* Kernel attribution -- ``kernels/ops.py`` reports first-call compile
  times and the dispatcher reports per-batch device waits here, keyed by
  kernel signature (op, l, T, B, backend).  ``kernel_records()`` returns
  the aggregate table that ``benchmarks/roofline_report.py`` renders as a
  per-stage roofline; the same numbers flow to the metrics registry as
  labelled counters.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, List, Optional

from . import trace
from .metrics import REGISTRY, Registry

__all__ = [
    "profile_span",
    "note_kernel",
    "kernel_records",
    "reset_kernels",
    "aggregate_device_spans",
]

_lock = threading.Lock()
_kernels: Dict[str, Dict[str, float]] = {}


@contextlib.contextmanager
def profile_span(name: str, out_dir: Optional[str] = None, **args: Any):
    """Span that optionally wraps a ``jax.profiler`` trace capture.

    With ``out_dir=None`` this is exactly ``trace.span``.  With a
    directory, a profiler session is started/stopped around the span body;
    failures to import or start the profiler degrade to a plain span (the
    span records ``profiler="unavailable"``).
    """
    started = False
    if out_dir is not None:
        try:
            import jax

            jax.profiler.start_trace(out_dir)
            started = True
        except Exception:
            args = dict(args, profiler="unavailable")
    try:
        with trace.span(name, **args) as sp:
            yield sp
    finally:
        if started:
            import jax

            jax.profiler.stop_trace()


def note_kernel(
    sig: str,
    compile_s: float = 0.0,
    execute_s: float = 0.0,
    calls: int = 0,
    flops: float = 0.0,
    nbytes: float = 0.0,
    registry: Optional[Registry] = None,
) -> None:
    """Accumulate compile/execute time for one kernel signature."""
    with _lock:
        rec = _kernels.setdefault(
            sig,
            {
                "compile_s": 0.0,
                "execute_s": 0.0,
                "calls": 0,
                "flops": 0.0,
                "bytes": 0.0,
            },
        )
        rec["compile_s"] += compile_s
        rec["execute_s"] += execute_s
        rec["calls"] += calls
        rec["flops"] += flops
        rec["bytes"] += nbytes
    reg = registry or REGISTRY
    if compile_s:
        reg.counter(
            "repro_kernel_compile_seconds_total",
            help="first-call compile time per kernel signature",
            sig=sig,
        ).inc(compile_s)
    if execute_s:
        reg.counter(
            "repro_kernel_execute_seconds_total",
            help="device execute/wait time per kernel signature",
            sig=sig,
        ).inc(execute_s)


def kernel_records() -> List[Dict[str, Any]]:
    """Per-signature attribution rows, sorted by execute time (desc)."""
    with _lock:
        rows = [dict(rec, sig=sig) for sig, rec in _kernels.items()]
    rows.sort(key=lambda r: -r["execute_s"])
    return rows


def reset_kernels() -> None:
    """Clear the attribution table (test isolation)."""
    with _lock:
        _kernels.clear()


def aggregate_device_spans(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Fold a Chrome trace doc into per-signature device rows.

    Groups complete events that carry a ``sig`` arg (the dispatcher's
    device spans) and sums duration/flops/bytes, yielding the same row
    shape as :func:`kernel_records` so ``roofline_report.py`` can render a
    roofline from an exported trace file alone.
    """
    by_sig: Dict[str, Dict[str, Any]] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        sig = args.get("sig")
        if not sig:
            continue
        rec = by_sig.setdefault(
            sig,
            {
                "sig": sig,
                "compile_s": 0.0,
                "execute_s": 0.0,
                "calls": 0,
                "flops": 0.0,
                "bytes": 0.0,
            },
        )
        dur_s = ev.get("dur", 0.0) / 1e6
        if ev.get("name") == "kernel/compile":
            rec["compile_s"] += dur_s
        else:
            rec["execute_s"] += dur_s
            rec["calls"] += 1
        rec["flops"] += float(args.get("flops", 0) or 0)
        rec["bytes"] += float(args.get("bytes", 0) or 0)
    rows = list(by_sig.values())
    rows.sort(key=lambda r: -r["execute_s"])
    return rows
