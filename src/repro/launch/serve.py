"""Serving launcher: batched prefill+decode loop over synthetic requests.

``python -m repro.launch.serve --arch granite-3-8b --requests 8 --tokens 16``
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    spec = configs.get(args.arch)
    assert spec.family == "lm", "serve launcher drives LM archs"
    cfg = spec.full if args.full else spec.reduced
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg)
    B, S = args.requests, args.prompt_len
    max_len = S + args.tokens
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)

    prefill_jit = jax.jit(
        lambda p, t: tr.prefill(p, t, cfg, max_len=max_len))
    decode_jit = jax.jit(
        lambda p, c, t, ln: tr.decode_step(p, c, t, ln, cfg))

    t0 = time.monotonic()
    logits, cache = prefill_jit(params, prompts)
    nxt = jnp.argmax(logits, -1)[:, None]
    lengths = jnp.full((B,), S, jnp.int32)
    out_tokens = [nxt]
    for _ in range(args.tokens - 1):
        logits, cache = decode_jit(params, cache, nxt, lengths)
        nxt = jnp.argmax(logits, -1)[:, None]
        lengths = lengths + 1
        out_tokens.append(nxt)
    gen = jnp.concatenate(out_tokens, axis=1)
    dt = time.monotonic() - t0
    print(f"served {B} requests x {args.tokens} tokens in {dt:.2f}s "
          f"({B * args.tokens / dt:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:10])


if __name__ == "__main__":
    main()
