"""Per-family step functions + abstract inputs + shardings for every
(architecture x shape) cell.  Used by dryrun.py (lower+compile), train.py
and serve.py (real execution at reduced scale)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchSpec, ShapeCell
from ..models import transformer as tr
from ..models import gnn as gnn_mod
from ..models import equivariant as eqv
from ..models import recsys as rec
from ..optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from ..sharding.rules import transformer_param_specs, transformer_cache_specs


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape) cell."""
    step_fn: Callable
    abstract_args: Tuple
    in_specs: Tuple
    out_specs: Any
    meta: Dict[str, Any]


def _axes_in_mesh(mesh: Optional[Mesh], axes: Tuple[str, ...]):
    if mesh is None:
        return None
    have = [a for a in axes if a in mesh.axis_names]
    if not have:
        return None
    return tuple(have) if len(have) > 1 else have[0]


def _filter_spec(spec: P, mesh: Optional[Mesh]) -> P:
    """Drop mesh axes that don't exist on this mesh (pod on single-pod)."""
    if mesh is None:
        return P()
    parts = []
    for part in spec:
        if part is None:
            parts.append(None)
        elif isinstance(part, str):
            parts.append(part if part in mesh.axis_names else None)
        else:
            kept = tuple(a for a in part if a in mesh.axis_names)
            parts.append(kept if len(kept) > 1 else
                         (kept[0] if kept else None))
    return P(*parts)


def _sharding_tree(mesh: Optional[Mesh], spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _filter_spec(s, mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def _opt_specs(param_specs):
    return {"mu": param_specs, "nu": param_specs, "count": P()}


DATA_AXES = ("pod", "data")
ALL_AXES = ("pod", "data", "model")


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _model_size(mesh: Optional[Mesh]) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def _lm_ctx(mesh: Optional[Mesh], cfg=None) -> tr.ShardCtx:
    if mesh is None:
        return tr.ShardCtx(mesh=None)
    da = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    lspecs = None
    if cfg is not None:
        from ..sharding.rules import transformer_layer_specs
        lspecs = transformer_layer_specs(cfg, _model_size(mesh))
    return tr.ShardCtx(mesh=mesh, data_axes=da, model_axis="model",
                       layer_specs=lspecs)


def lm_train_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                  reduced: bool = False, microbatches: int = 16) -> Cell:
    cfg: tr.TransformerConfig = spec.reduced if reduced else spec.full
    ctx = _lm_ctx(mesh, cfg)
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    if reduced:
        B, S = 2, min(S, 64)
        microbatches = 1
    M = microbatches if B % microbatches == 0 else 1
    opt_cfg = AdamWConfig(lr=3e-4, schedule=cosine_schedule(100, 10000))

    def step(params, opt_state, batch):
        """Gradient-accumulated train step: M microbatches keep per-pass
        activation residuals (the scan carry x per layer) at 1/M of the
        global batch -- the knob that fits 132B-scale training in HBM."""
        def one_micro(carry, mb):
            g_acc, loss_acc = carry
            loss, g = jax.value_and_grad(
                lambda p: tr.loss_fn(p, mb, cfg, ctx))(params)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return (g_acc, loss_acc + loss), None

        mb_batch = jax.tree.map(
            lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(
            one_micro, (zeros, jnp.float32(0.0)), mb_batch,
            unroll=M if cfg.analysis_unroll else 1)
        grads = jax.tree.map(lambda g: g / M, grads)
        loss = loss / M
        params, opt_state, m = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **m}

    params_abs = jax.eval_shape(
        lambda k: tr.init_params(k, cfg), jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    pspec = transformer_param_specs(cfg, model_size=_model_size(mesh))
    bspec = {"tokens": P(DATA_AXES, None), "labels": P(DATA_AXES, None)}
    mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return Cell(
        step_fn=step,
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_specs=(_sharding_tree(mesh, pspec),
                  _sharding_tree(mesh, _opt_specs(pspec)),
                  _sharding_tree(mesh, bspec)),
        out_specs=(_sharding_tree(mesh, pspec),
                   _sharding_tree(mesh, _opt_specs(pspec)),
                   _sharding_tree(mesh, mspec)),
        meta={"tokens_per_step": B * S,
              "model_params": cfg.num_params(),
              "active_params": cfg.active_params()},
    )


def lm_prefill_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                    reduced: bool = False) -> Cell:
    cfg = spec.reduced if reduced else spec.full
    ctx = _lm_ctx(mesh, cfg)
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    if reduced:
        B, S = 2, min(S, 64)

    def step(params, tokens):
        return tr.prefill(params, tokens, cfg, max_len=S, ctx=ctx)

    params_abs = jax.eval_shape(
        lambda k: tr.init_params(k, cfg), jax.random.PRNGKey(0))
    tok_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    pspec = transformer_param_specs(cfg, model_size=_model_size(mesh))
    cspec = transformer_cache_specs(cfg, model_size=_model_size(mesh))
    logit_spec = P(DATA_AXES, "model")
    return Cell(
        step_fn=step,
        abstract_args=(params_abs, tok_abs),
        in_specs=(_sharding_tree(mesh, pspec),
                  _sharding_tree(mesh, {"t": P(DATA_AXES, None)})["t"]
                  if mesh else None),
        out_specs=(_sharding_tree(mesh, {"l": logit_spec})["l"]
                   if mesh else None,
                   _sharding_tree(mesh, cspec)),
        meta={"tokens_per_step": B * S,
              "model_params": cfg.num_params(),
              "active_params": cfg.active_params()},
    )


def lm_decode_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                   reduced: bool = False) -> Cell:
    cfg = spec.reduced if reduced else spec.full
    ctx = _lm_ctx(mesh, cfg)
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    if reduced:
        B, S = 2, min(S, 64)
    long_ctx = B == 1  # long_500k: shard the KV length, not the batch

    def step(params, cache, tokens, lengths):
        return tr.decode_step(params, cache, tokens, lengths, cfg, ctx)

    params_abs = jax.eval_shape(
        lambda k: tr.init_params(k, cfg), jax.random.PRNGKey(0))
    cache_abs = jax.eval_shape(
        functools.partial(tr.init_cache, cfg, B, S))
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    len_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    pspec = transformer_param_specs(cfg, model_size=_model_size(mesh))
    kv_shardable = cfg.n_kv_heads % max(_model_size(mesh), 1) == 0
    if long_ctx:
        kv = P(None, None, DATA_AXES, "model" if kv_shardable else None,
               None)
        cspec = {kind: {"k": kv, "v": kv} for kind, _ in cfg.layer_groups}
        tspec, lspec = P(None, None), P(None)
        ologit = P(None, "model")
    else:
        if kv_shardable:
            kv = P(None, DATA_AXES, None, "model", None)
        else:
            # GQA with kv < TP: shard the cache *length* over the model
            # axis instead (a replicated 32k cache is 100+ GB/device)
            kv = P(None, DATA_AXES, "model", None, None)
        cspec = {kind: {"k": kv, "v": kv} for kind, _ in cfg.layer_groups}
        tspec, lspec = P(DATA_AXES, None), P(DATA_AXES)
        ologit = P(DATA_AXES, "model")
    return Cell(
        step_fn=step,
        abstract_args=(params_abs, cache_abs, tok_abs, len_abs),
        in_specs=(_sharding_tree(mesh, pspec), _sharding_tree(mesh, cspec),
                  _sharding_tree(mesh, {"x": tspec})["x"] if mesh else None,
                  _sharding_tree(mesh, {"x": lspec})["x"] if mesh else None),
        out_specs=(_sharding_tree(mesh, {"x": ologit})["x"] if mesh else None,
                   _sharding_tree(mesh, cspec)),
        meta={"tokens_per_step": B,
              "kv_cache_tokens": S,
              "model_params": cfg.num_params(),
              "active_params": cfg.active_params()},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _pad_up(x: int, mult: int = 512) -> int:
    """Graph batches are padded to a multiple of the full mesh size (the
    data pipeline emits edge_mask/padded isolated nodes); production
    sharding requires divisibility."""
    return -(-x // mult) * mult


def _gnn_batch_abs(spec: ArchSpec, cell: ShapeCell, reduced: bool):
    d = dict(cell.dims)
    if "batch" in d:      # molecule: batched small graphs
        B = 4 if reduced else d["batch"]
        N = d["n_nodes"] * B
        E = d["n_edges"] * B
        n_graphs = B
    elif "batch_nodes" in d:   # sampled minibatch: union block graph
        bn = 64 if reduced else d["batch_nodes"]
        f0, f1 = d["fanout0"], d["fanout1"]
        N = bn + bn * f0 + bn * f0 * f1
        E = bn * f0 + bn * f0 * f1
        n_graphs = 1
    else:
        N = 128 if reduced else d["n_nodes"]
        E = 512 if reduced else d["n_edges"]
        n_graphs = 1
    if not reduced:
        N, E = _pad_up(N), _pad_up(E)
    d_feat = 8 if reduced else d.get("d_feat", 16)
    n_classes = d.get("n_classes", 2)
    return N, E, d_feat, n_classes, n_graphs


def _gnn_wsc(mesh: Optional[Mesh]):
    """Sharding-constraint callback for GNN internals: node/edge arrays stay
    sharded over all mesh axes through gather/scatter (without this, GSPMD
    materializes full replicated node arrays per layer -- measured ~5 GB/
    layer on ogb_products; EXPERIMENTS.md section Perf)."""
    if mesh is None:
        return lambda x, kind: x
    axes = tuple(a for a in ALL_AXES if a in mesh.axis_names)

    def wsc(x, kind):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return wsc


def gnn_train_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                   reduced: bool = False) -> Cell:
    N, E, d_feat, n_classes, n_graphs = _gnn_batch_abs(spec, cell, reduced)
    base = spec.reduced if reduced else spec.full
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    name = spec.name
    wsc = _gnn_wsc(mesh)

    if name == "gin-tu":
        cfg = dataclasses.replace(base, d_in=d_feat, n_classes=n_classes,
                                  graph_level=False)
        def init(k):
            return gnn_mod.init_gin(k, cfg)

        def loss_of(params, batch):
            logits = gnn_mod.gin_forward(params, batch["nodes"],
                                         batch["edges"], batch["edge_mask"],
                                         cfg, wsc=wsc)
            oh = jax.nn.one_hot(batch["labels"], cfg.n_classes)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            return -(oh * logp).sum(-1).mean()

        batch_abs = {
            "nodes": jax.ShapeDtypeStruct((N, d_feat), jnp.float32),
            "edges": jax.ShapeDtypeStruct((2, E), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((E,), jnp.float32),
            "labels": jax.ShapeDtypeStruct((N,), jnp.int32),
        }
        bspec = {"nodes": P(ALL_AXES, None), "edges": P(None, ALL_AXES),
                 "edge_mask": P(ALL_AXES), "labels": P(ALL_AXES)}
    elif name == "meshgraphnet":
        d_edge = 4
        cfg = dataclasses.replace(base, d_node_in=d_feat, d_edge_in=d_edge,
                                  d_out=n_classes,
                                  scan_layers=not reduced)
        def init(k):
            return gnn_mod.init_mgn(k, cfg)

        def loss_of(params, batch):
            out = gnn_mod.mgn_forward(params, batch["nodes"],
                                      batch["edge_feats"], batch["edges"],
                                      batch["edge_mask"], cfg, wsc=wsc)
            return jnp.mean((out - batch["targets"]) ** 2)

        batch_abs = {
            "nodes": jax.ShapeDtypeStruct((N, d_feat), jnp.float32),
            "edge_feats": jax.ShapeDtypeStruct((E, d_edge), jnp.float32),
            "edges": jax.ShapeDtypeStruct((2, E), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((E,), jnp.float32),
            "targets": jax.ShapeDtypeStruct((N, n_classes), jnp.float32),
        }
        bspec = {"nodes": P(ALL_AXES, None),
                 "edge_feats": P(ALL_AXES, None),
                 "edges": P(None, ALL_AXES), "edge_mask": P(ALL_AXES),
                 "targets": P(ALL_AXES, None)}
    elif name == "egnn":
        cfg = dataclasses.replace(base, d_in=d_feat, d_out=1)
        def init(k):
            return gnn_mod.init_egnn(k, cfg)

        def loss_of(params, batch):
            out, _ = gnn_mod.egnn_forward(
                params, batch["nodes"], batch["pos"], batch["edges"],
                batch["edge_mask"], cfg, batch["graph_ids"], n_graphs,
                wsc=wsc)
            return jnp.mean((out[:, 0] - batch["energy"]) ** 2)

        batch_abs = {
            "nodes": jax.ShapeDtypeStruct((N, d_feat), jnp.float32),
            "pos": jax.ShapeDtypeStruct((N, 3), jnp.float32),
            "edges": jax.ShapeDtypeStruct((2, E), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((E,), jnp.float32),
            "graph_ids": jax.ShapeDtypeStruct((N,), jnp.int32),
            "energy": jax.ShapeDtypeStruct((n_graphs,), jnp.float32),
        }
        bspec = {"nodes": P(ALL_AXES, None), "pos": P(ALL_AXES, None),
                 "edges": P(None, ALL_AXES), "edge_mask": P(ALL_AXES),
                 "graph_ids": P(ALL_AXES), "energy": P(None)}
    elif name == "nequip":
        cfg = dataclasses.replace(base, scan_layers=not reduced)

        def init(k):
            return eqv.init_nequip(k, cfg)

        def loss_of(params, batch):
            out = eqv.nequip_forward(
                params, batch["species"], batch["pos"], batch["edges"],
                batch["edge_mask"], cfg, batch["graph_ids"], n_graphs,
                wsc=wsc)
            return jnp.mean((out[:, 0] - batch["energy"]) ** 2)

        batch_abs = {
            "species": jax.ShapeDtypeStruct((N, cfg.n_species), jnp.float32),
            "pos": jax.ShapeDtypeStruct((N, 3), jnp.float32),
            "edges": jax.ShapeDtypeStruct((2, E), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((E,), jnp.float32),
            "graph_ids": jax.ShapeDtypeStruct((N,), jnp.int32),
            "energy": jax.ShapeDtypeStruct((n_graphs,), jnp.float32),
        }
        bspec = {"species": P(ALL_AXES, None), "pos": P(ALL_AXES, None),
                 "edges": P(None, ALL_AXES), "edge_mask": P(ALL_AXES),
                 "graph_ids": P(ALL_AXES), "energy": P(None)}
    else:
        raise KeyError(name)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params, opt_state, m = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **m}

    params_abs = jax.eval_shape(init, jax.random.PRNGKey(0))
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    rp = jax.tree.map(lambda _: P(), params_abs)
    mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return Cell(
        step_fn=step,
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_specs=(_sharding_tree(mesh, rp),
                  _sharding_tree(mesh, _opt_specs(rp)),
                  _sharding_tree(mesh, bspec)),
        out_specs=(_sharding_tree(mesh, rp),
                   _sharding_tree(mesh, _opt_specs(rp)),
                   _sharding_tree(mesh, mspec)),
        meta={"n_nodes": N, "n_edges": E},
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                reduced: bool = False) -> Cell:
    cfg: rec.DCNConfig = spec.reduced if reduced else spec.full
    kind = cell.kind
    B = cell.dims.get("batch", 256)
    if reduced:
        B = min(B, 16)
    opt_cfg = AdamWConfig(lr=1e-3)

    params_abs = jax.eval_shape(
        lambda k: rec.init_dcn(k, cfg), jax.random.PRNGKey(0))
    pspec = jax.tree.map(lambda _: P(), params_abs)
    pspec["table"] = P("model", None)
    dense_abs = jax.ShapeDtypeStruct((B, cfg.n_dense), jnp.float32)
    sparse_abs = jax.ShapeDtypeStruct((B, cfg.n_sparse, cfg.bag), jnp.int32)
    bspec_d, bspec_s = P(DATA_AXES, None), P(DATA_AXES, None, None)

    if kind == "train":
        def step(params, opt_state, batch):
            def lf(p):
                logits = rec.dcn_forward(p, batch["dense"], batch["sparse"],
                                         cfg)
                return rec.bce_loss(logits, batch["labels"])
            loss, grads = jax.value_and_grad(lf)(params)
            params, opt_state, m = adamw_update(grads, opt_state, params,
                                                opt_cfg)
            return params, opt_state, {"loss": loss, **m}

        opt_abs = jax.eval_shape(adamw_init, params_abs)
        batch_abs = {"dense": dense_abs, "sparse": sparse_abs,
                     "labels": jax.ShapeDtypeStruct((B,), jnp.float32)}
        bspec = {"dense": bspec_d, "sparse": bspec_s, "labels": P(DATA_AXES)}
        mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Cell(step, (params_abs, opt_abs, batch_abs),
                    (_sharding_tree(mesh, pspec),
                     _sharding_tree(mesh, _opt_specs(pspec)),
                     _sharding_tree(mesh, bspec)),
                    (_sharding_tree(mesh, pspec),
                     _sharding_tree(mesh, _opt_specs(pspec)),
                     _sharding_tree(mesh, mspec)),
                    meta={"batch": B})
    if kind == "serve":
        def step(params, dense, sparse):
            return rec.dcn_forward(params, dense, sparse, cfg)

        return Cell(step, (params_abs, dense_abs, sparse_abs),
                    (_sharding_tree(mesh, pspec),
                     _sharding_tree(mesh, {"x": bspec_d})["x"] if mesh else None,
                     _sharding_tree(mesh, {"x": bspec_s})["x"] if mesh else None),
                    _sharding_tree(mesh, {"x": P(DATA_AXES)})["x"]
                    if mesh else None,
                    meta={"batch": B})
    if kind == "retrieval":
        n_cand = cell.dims["n_candidates"]
        if reduced:
            n_cand = 4096
        dt = cfg.mlp_dims[-1]
        cand_abs = jax.ShapeDtypeStruct((n_cand, dt), jnp.float32)

        def step(params, dense, sparse, cand):
            v, i = rec.retrieval_scores(params, dense, sparse, cand, cfg,
                                        topk=min(100, n_cand))
            return (v, i)

        ospec = (P(None, None), P(None, None))
        return Cell(step, (params_abs, dense_abs, sparse_abs, cand_abs),
                    (_sharding_tree(mesh, pspec),
                     _sharding_tree(mesh, {"x": P(None, None)})["x"]
                     if mesh else None,
                     _sharding_tree(mesh, {"x": P(None, None, None)})["x"]
                     if mesh else None,
                     _sharding_tree(mesh, {"x": P("model", None)})["x"]
                     if mesh else None),
                    _sharding_tree(mesh, ospec),
                    meta={"batch": B, "n_candidates": n_cand})
    raise KeyError(kind)


# ---------------------------------------------------------------------------
# clique-engine cells (the paper's own arch)
# ---------------------------------------------------------------------------

def clique_cell(spec: ArchSpec, cell: ShapeCell, mesh: Optional[Mesh],
                reduced: bool = False) -> Cell:
    from ..core import engine_jax
    d = dict(cell.dims)
    B = 256 if reduced else d["n_tiles"]
    T = 32 if reduced else d["T"]
    l = d["l"]
    W = T // 32
    method = "mxu" if l == 3 else "ref"

    def local_count(A, cand):
        hard, nv, t, f = engine_jax.count_packed(
            A, cand, l, method=method, et=True, interpret=True)
        total = hard.astype(jnp.float32).sum()
        if mesh is not None:
            total = jax.lax.psum(total, ALL_AXES[-len(mesh.axis_names):])
        return total, nv, t, f

    if mesh is None:
        step = local_count
    else:
        axes = tuple(a for a in ALL_AXES if a in mesh.axis_names)

        def step(A, cand):
            def inner(A_loc, cand_loc):
                hard, nv, t, f = engine_jax.count_packed(
                    A_loc, cand_loc, l, method=method, et=True,
                    interpret=True)
                total = jax.lax.psum(hard.astype(jnp.float32).sum(), axes)
                return total, nv, t, f
            return jax.shard_map(
                inner, mesh=mesh,
                in_specs=(P(axes, None, None), P(axes, None)),
                out_specs=(P(), P(axes), P(axes), P(axes)),
                check_vma=False)(A, cand)

    A_abs = jax.ShapeDtypeStruct((B, T, W), jnp.uint32)
    cand_abs = jax.ShapeDtypeStruct((B, W), jnp.uint32)
    ts = P(ALL_AXES, None, None)
    cs = P(ALL_AXES, None)
    return Cell(
        step_fn=step,
        abstract_args=(A_abs, cand_abs),
        in_specs=(_sharding_tree(mesh, {"x": ts})["x"] if mesh else None,
                  _sharding_tree(mesh, {"x": cs})["x"] if mesh else None),
        out_specs=(_sharding_tree(
            mesh, {"x": (P(), P(ALL_AXES), P(ALL_AXES), P(ALL_AXES))})["x"]
            if mesh else None),
        meta={"n_tiles": B, "T": T, "l": l, "method": method},
    )


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_cell(spec: ArchSpec, shape_name: str, mesh: Optional[Mesh],
               reduced: bool = False) -> Cell:
    cell = spec.cells[shape_name]
    if cell.skip:
        raise ValueError(f"cell {spec.name}/{shape_name} is skipped: "
                         f"{cell.skip}")
    if spec.family == "lm":
        if cell.kind == "train":
            return lm_train_cell(spec, cell, mesh, reduced)
        if cell.kind == "prefill":
            return lm_prefill_cell(spec, cell, mesh, reduced)
        if cell.kind == "decode":
            return lm_decode_cell(spec, cell, mesh, reduced)
    if spec.family == "gnn":
        return gnn_train_cell(spec, cell, mesh, reduced)
    if spec.family == "recsys":
        return recsys_cell(spec, cell, mesh, reduced)
    if spec.family == "clique":
        return clique_cell(spec, cell, mesh, reduced)
    raise KeyError((spec.family, cell.kind))
