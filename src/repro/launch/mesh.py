"""Production mesh construction (multi-pod dry-run spec).

A function, not a module-level constant: importing this module never
touches jax device state."""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dryrun.py sets xla_force_host_platform_device_count)")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices exist (tests / smoke)."""
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
