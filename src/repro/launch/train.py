"""Training launcher: ``python -m repro.launch.train --arch granite-3-8b``.

Runs the fault-tolerant TrainLoop on whatever devices exist (reduced config
by default on CPU; ``--full`` requires a real fleet).  Auto-resumes from
--ckpt-dir if a committed checkpoint exists.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from .. import configs
from ..data import LMDataPipeline, RecsysPipeline
from ..optim import adamw_init
from ..runtime import TrainLoop, TrainLoopConfig
from .steps import build_cell


def make_pipeline(spec, cell_cfg, cell, reduced: bool):
    if spec.family == "lm":
        cfg = spec.reduced if reduced else spec.full
        B, S = cell.abstract_args[2]["tokens"].shape
        return LMDataPipeline(vocab=cfg.vocab, batch=B, seq_len=S)
    if spec.family == "gnn":
        meta = cell.meta
        batch_abs = cell.abstract_args[2]

        class _GnnPipe:
            def __init__(self):
                self.step = 0

            def next_batch(self):
                rng = np.random.default_rng([7, self.step])
                self.step += 1
                out = {}
                for k, v in batch_abs.items():
                    if np.issubdtype(v.dtype, np.integer):
                        hi = max(meta["n_nodes"], 2)
                        out[k] = rng.integers(
                            0, hi, v.shape).astype(v.dtype)
                    else:
                        out[k] = rng.normal(size=v.shape).astype(v.dtype)
                if "edge_mask" in out:
                    out["edge_mask"] = np.ones_like(out["edge_mask"])
                return out

            def state(self):
                return {"step": self.step}

            def restore(self, s):
                self.step = int(s["step"])

        return _GnnPipe()
    if spec.family == "recsys":
        cfg = spec.reduced if reduced else spec.full
        B = cell.abstract_args[2]["dense"].shape[0]
        return RecsysPipeline(n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
                              vocab=cfg.vocab, batch=B, bag=cfg.bag)
    raise KeyError(spec.family)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    shape = args.shape or next(
        n for n, c in spec.cells.items() if c.kind == "train" and not c.skip)
    cell = build_cell(spec, shape, mesh=None, reduced=not args.full)
    cell_cfg = spec.cells[shape]

    params = jax.tree.map(
        lambda s: jax.random.normal(jax.random.PRNGKey(1), s.shape,
                                    s.dtype) * 0.02
        if np.issubdtype(s.dtype, np.floating)
        else np.zeros(s.shape, s.dtype),
        cell.abstract_args[0],
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # proper init where families expose one
    if spec.family == "lm":
        from ..models.transformer import init_params
        params = init_params(jax.random.PRNGKey(1),
                             spec.reduced if not args.full else spec.full)
    opt_state = adamw_init(params)
    step_jit = jax.jit(cell.step_fn)
    pipeline = make_pipeline(spec, cell_cfg, cell, reduced=not args.full)

    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps,
                        checkpoint_dir=args.ckpt_dir,
                        checkpoint_every=args.ckpt_every,
                        fail_at_step=args.fail_at),
        lambda p, o, b: step_jit(p, o, b), params, opt_state, pipeline)
    out = loop.run()
    m = {k: float(np.asarray(v)) for k, v in out["metrics"].items()}
    print(f"done at step {out['final_step']}: {m}")


if __name__ == "__main__":
    main()
