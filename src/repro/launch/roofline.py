"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e-like, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds, per device -- the compiled module is the per-device SPMD
program, so its FLOPs/bytes are already per-chip):
  compute    = flops / peak_flops
  memory     = bytes_accessed / hbm_bw
  collective = collective_operand_bytes / ici_bw
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12         # bf16 FLOP/s per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (1 link assumed conservative)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective-op-kind: instruction count + operand & result bytes.

    HLO text prints operands as bare SSA refs, so operand bytes are derived
    from the result shape + op semantics:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather: operand = result / group_size
      reduce-scatter: operand = result * group_size
    """
    out = {k: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
           for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        for op in _COLL_OPS:
            # match "= <ty> op(" and async "op-start("
            m = re.search(rf"= (.*?)\b{op}(?:-start)?\(", s)
            if not m:
                continue
            if f"{op}-done" in s:
                continue
            result_part = m.group(1)
            rb = sum(_shape_bytes(t, d)
                     for t, d in _SHAPE_RE.findall(result_part))
            g = _group_size(s)
            if op == "all-gather":
                ob = rb // max(g, 1)
            elif op == "reduce-scatter":
                ob = rb * g
            else:
                ob = rb
            out[op]["count"] += 1
            out[op]["operand_bytes"] += ob
            out[op]["result_bytes"] += rb
            break
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_operand_bytes: float) -> Dict[str, float]:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_operand_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute, memory, collective)
    terms["bound_s"] = total
    for k in ("compute_s", "memory_s", "collective_s"):
        terms[f"frac_{k[:-2]}"] = (terms[k] / total) if total > 0 else 0.0
    return terms


def model_flops_lm(meta: Dict, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: per token."""
    n = meta.get("active_params") or meta.get("model_params") or 0
    toks = meta.get("tokens_per_step", 0)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * toks
