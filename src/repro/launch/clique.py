"""Distributed k-clique counting driver (the paper's operator as a service).

``python -m repro.launch.clique --graph rmat:14 --k 5 --devices all``

Pipeline: host preprocessing (truss order cached in a PipelinePlan) ->
vectorized extraction + capacity-batched packing (repro.core.pipeline) ->
LPT cost-balanced batch scheduling (Section 6.2(7) EdgeParallel; scheduler
bins map one-to-one onto real local devices, repro.runtime.dispatch) ->
per-device jit kernels with double-buffered host->device staging -> exact
host combine.  Oversize tiles spill to the host recursion instead of
aborting.  On this CPU container it runs on however many host devices
exist (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` forges N);
the 512-way layout is exercised by dryrun.py.
"""
from __future__ import annotations

import argparse
import time

from ..core import ebbkc, engine_jax, listing, pipeline
from ..core import tiles as tiles_mod
from ..core.engine_np import Stats
from ..core.graph import Graph
from ..data import graphs as gdata
from ..launch.mesh import make_local_mesh
from ..obs import metrics as obs_metrics
from ..obs import trace
from ..obs.export import MetricsServer
from ..obs.logging import LEVELS, get_logger, setup_logging
from ..runtime.dispatch import (Dispatcher, dispatch_scheduled,
                                resolve_devices)
from .. import tune
from ..tune import search as tune_search


def load_graph(desc: str) -> Graph:
    kind, _, arg = desc.partition(":")
    if kind == "rmat":
        return gdata.rmat_graph(int(arg or 12), edge_factor=8, seed=7)
    if kind == "er":
        n, p = arg.split(",")
        return gdata.erdos_renyi(int(n), float(p), seed=7)
    if kind == "powerlaw":
        return gdata.powerlaw_graph(int(arg or 2000), 16, seed=7)
    if kind == "planted":
        return gdata.planted_cliques(int(arg or 2000), 30, 12, seed=7)
    raise ValueError(f"unknown graph spec {desc}")


def parse_devices(spec: str):
    """CLI device spec: "all" or an int count (graceful clamp)."""
    return "all" if spec == "all" else int(spec)


def _finish_obs(args, stats, metrics_server):
    """Flush run observability: publish stats, export trace, stop server."""
    if stats is not None:
        obs_metrics.observe_stats(stats)
    if args.trace_out:
        trace.export(args.trace_out)
        print(f"trace: wrote {args.trace_out} "
              f"({len(trace.events())} events, "
              f"{trace.dropped()} dropped)")
    if metrics_server is not None:
        metrics_server.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--order", default="hybrid")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="tiles per packed batch (default: tuned geometry "
                         "record if present, else 256)")
    ap.add_argument("--devices", default="all",
                    help='"all" or device count (clamped to available)')
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "lax", "ref", "autotune"],
                    help="kernel backend (repro.kernels.ops registry); "
                         "default auto = compiled lax off-TPU, Mosaic "
                         "Pallas on TPU; also settable via REPRO_BACKEND")
    ap.add_argument("--shard-map", action="store_true",
                    help="shard each batch over a device mesh instead of "
                         "LPT-placing whole batches on devices")
    ap.add_argument("--offline-lpt", action="store_true",
                    help="materialize all batches, then map schedule_batches"
                         " LPT bins one-to-one onto devices (prints balance;"
                         " default is streaming online-LPT dispatch, which"
                         " overlaps packing with device execution and keeps"
                         " host memory bounded)")
    ap.add_argument("--sync-staging", action="store_true",
                    help="disable double-buffered host->device staging")
    ap.add_argument("--list", action="store_true", dest="list_mode",
                    help="materialize the cliques through the emission "
                         "subsystem instead of counting them")
    ap.add_argument("--sink", default=None, metavar="PATH",
                    help="with --list: write the cliques to PATH as an NPZ "
                         "(key 'cliques'); default is an in-memory buffer")
    ap.add_argument("--max-out", type=int, default=None,
                    help="with --list: stop after this many cliques")
    ap.add_argument("--pack-workers", type=int, default=None,
                    help="parallel pack-producer threads (default auto; "
                         "0 = serial inline packing)")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="persist the PipelinePlan (truss order + tile "
                         "tables) under DIR, keyed by graph content: a "
                         "repeated invocation on the same graph skips the "
                         "O(delta m) decomposition entirely")
    ap.add_argument("--tune-cache", default=None, metavar="DIR",
                    help="persistent autotuner directory (repro.tune): "
                         "backend/geometry tuning records plus JAX's "
                         "persistent compilation cache, so a repeated "
                         "invocation skips microbenchmarks AND XLA "
                         "compiles; also settable via REPRO_TUNE_CACHE")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="chaos mode: seeded fault-injection plan for "
                         "repro.resilience (e.g. 'seed=7;*=0.1;"
                         "kernel.launch=0.3'); results stay exact via "
                         "retry/demotion; also settable via "
                         "REPRO_FAULT_PLAN")
    ap.add_argument("--verify", action="store_true",
                    help="cross-check against the host engine")
    ap.add_argument("--log-level", default="warning", choices=list(LEVELS),
                    help="repro.* logger verbosity (obs/logging)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a structured span trace of the whole run "
                         "and write it as Chrome/Perfetto trace_event JSON "
                         "(open at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus /metrics on 127.0.0.1:PORT for "
                         "the run's duration (0 = ephemeral port)")
    args = ap.parse_args()

    setup_logging(args.log_level)
    log = get_logger("launch.clique")
    if args.trace_out:
        trace.configure(enabled=True)
    metrics_server = None
    if args.metrics_port is not None:
        metrics_server = MetricsServer(port=args.metrics_port)
        print(f"metrics: {metrics_server.address}/metrics")
    if args.tune_cache:
        tune.configure(args.tune_cache)
    if args.fault_plan:
        from ..resilience import inject

        inject.configure(args.fault_plan)
        print(f"fault injection: {args.fault_plan}")
    g = load_graph(args.graph)
    log.info("loaded %s: n=%d m=%d", args.graph, g.n, g.m)
    print(f"graph: n={g.n} m={g.m}")
    l = args.k - 2
    devices = resolve_devices(parse_devices(args.devices))
    n_dev = len(devices)
    mesh = None
    if args.shard_map:
        mesh = make_local_mesh((n_dev, 1), axes=("data", "model"))

    t0 = time.time()
    plan_stats = Stats()
    plan = pipeline.cached_plan(g, order=args.order,
                                cache_dir=args.plan_cache, stats=plan_stats)
    t_plan = time.time() - t0
    if args.plan_cache:
        src = "warm (decomposition skipped)" if plan_stats.plan_cache_hit \
            else f"cold (built in {plan_stats.plan_build_s:.2f}s, saved)"
        print(f"plan cache [{args.plan_cache}]: {src}")

    if args.list_mode:
        sink = (listing.NpzSink(args.sink, args.k, max_out=args.max_out)
                if args.sink
                else listing.ArraySink(args.k, max_out=args.max_out))
        t0 = time.time()
        res = listing.stream_cliques(
            plan, args.k, sink, order=args.order,
            batch_size=args.batch_size, devices=devices,
            backend=args.backend,
            pack_workers=args.pack_workers,
            async_staging=not args.sync_staging)
        t_list = time.time() - t0
        sink.close()
        st = res.stats
        rate = st.emitted_cliques / max(t_list, 1e-9)
        print(f"k={args.k}: listed {st.emitted_cliques} cliques in "
              f"{t_list:.2f}s ({rate:.0f} cliques/s, "
              f"{st.sink_bytes} sink bytes"
              f"{', -> ' + args.sink if args.sink else ''})")
        print(f"tiles={res.tiles} spilled={st.spilled_tiles} "
              f"overflowed={st.overflowed_tiles} devices={n_dev} "
              f"backend={st.backend} compile={st.kernel_compile_s:.2f}s "
              f"tune={st.tune_s:.2f}s tune_hit={st.tune_cache_hit} "
              f"pack_workers={st.pack_workers} "
              f"frontend={st.frontend_s:.2f}s "
              f"queue_occ={st.pack_queue_occupancy:.2f}")
        if args.verify:
            ref = ebbkc.count(g, args.k, order=args.order, plan=plan).count
            want = ref if args.max_out is None else min(args.max_out, ref)
            print(f"host count: {ref}  match={want == st.emitted_cliques}")
        _finish_obs(args, st, metrics_server)
        return

    stats = Stats()
    stage = {}
    geom = tune_search.resolve_geometry("count", l,
                                        batch_size=args.batch_size,
                                        pack_workers=args.pack_workers)
    stream = pipeline.stream_batches(plan, args.k, order=args.order,
                                     batch_size=geom.batch_size,
                                     bins=geom.bins,
                                     timings=stage,
                                     pack_workers=geom.pack_workers,
                                     prefetch=geom.prefetch,
                                     stats=stats)
    t0 = time.time()
    info = {}
    n_batches = 0
    n_tiles = 0
    if args.offline_lpt:
        # materialize, then scheduler bins become real devices
        batches = []
        total = 0
        for item in stream:
            if isinstance(item, tiles_mod.Tile):
                n_tiles += 1
                total += engine_jax.count_spilled(item, args.order, l, stats,
                                                  et_t=3, use_rule2=True)
            else:
                batches.append(item)
                n_tiles += item.B
        n_batches = len(batches)
        got, info = dispatch_scheduled(
            batches, l, devices, mesh=mesh, backend=args.backend,
            async_staging=not args.sync_staging, stats=stats)
        total += got
    else:
        # streaming: pack(i+1) on the host overlaps kernel(i) on devices
        disp = Dispatcher(l, devices, mesh=mesh, backend=args.backend,
                          async_staging=not args.sync_staging, stats=stats)
        total = 0
        for item in stream:
            if isinstance(item, tiles_mod.Tile):
                n_tiles += 1
                total += engine_jax.count_spilled(item, args.order, l, stats,
                                                  et_t=3, use_rule2=True)
            else:
                n_batches += 1
                n_tiles += item.B
                disp.submit(item)
        total += disp.finish()
    t_count = time.time() - t0
    # packing is interleaved with counting; stream_batches bills it apart
    t_pack = stage.get("extract", 0.0) + stage.get("pack", 0.0)

    balance = info.get("max_over_mean")
    bal_txt = f" balance max/mean={balance:.3f}" if balance else ""
    print(f"batches={n_batches} tiles={n_tiles} "
          f"spilled={stats.spilled_tiles} devices={n_dev}"
          f"{' (shard_map)' if mesh is not None else ''}{bal_txt}")
    per_dev = " ".join(
        f"d{d}:{stats.device_tiles[d]}t/{stats.device_flops[d] / 1e6:.0f}MF"
        for d in sorted(stats.device_tiles))
    print(f"device tiles/flops: {per_dev or '-'} "
          f"staging_overlap={stats.staging_overlap_s:.2f}s "
          f"backend={stats.backend} compile={stats.kernel_compile_s:.2f}s "
          f"tune={stats.tune_s:.2f}s tune_hit={stats.tune_cache_hit}")
    print(f"k={args.k}: {total} cliques "
          f"(plan {t_plan:.2f}s, front-to-finish {t_count:.2f}s, "
          f"of which extract+pack {t_pack:.2f}s; "
          f"pack_workers={stats.pack_workers} "
          f"queue_occ={stats.pack_queue_occupancy:.2f})")
    if args.verify:
        ref = ebbkc.count(g, args.k, order=args.order, plan=plan).count
        print(f"host engine: {ref}  match={ref == total}")
    _finish_obs(args, stats, metrics_server)


if __name__ == "__main__":
    main()
