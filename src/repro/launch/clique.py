"""Distributed k-clique counting driver (the paper's operator as a service).

``python -m repro.launch.clique --graph rmat:14 --k 5``

Pipeline: host preprocessing (truss order cached in a PipelinePlan) ->
vectorized extraction + capacity-batched packing (repro.core.pipeline) ->
LPT cost-balanced batch scheduling (Section 6.2(7) EdgeParallel; device
bins map one-to-one onto packed batches) -> device kernels -> psum.
Oversize tiles spill to the host recursion instead of aborting.
On this CPU container it runs on however many host devices exist; the
512-way layout is exercised by dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..core import ebbkc, engine_jax, pipeline
from ..core import tiles as tiles_mod
from ..core.engine_np import Stats
from ..core.graph import Graph
from ..data import graphs as gdata
from ..runtime.clique_scheduler import schedule_batches


def load_graph(desc: str) -> Graph:
    kind, _, arg = desc.partition(":")
    if kind == "rmat":
        return gdata.rmat_graph(int(arg or 12), edge_factor=8, seed=7)
    if kind == "er":
        n, p = arg.split(",")
        return gdata.erdos_renyi(int(n), float(p), seed=7)
    if kind == "powerlaw":
        return gdata.powerlaw_graph(int(arg or 2000), 16, seed=7)
    if kind == "planted":
        return gdata.planted_cliques(int(arg or 2000), 30, 12, seed=7)
    raise ValueError(f"unknown graph spec {desc}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--order", default="hybrid")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check against the host engine")
    args = ap.parse_args()

    g = load_graph(args.graph)
    print(f"graph: n={g.n} m={g.m}")
    l = args.k - 2
    n_dev = jax.device_count()

    t0 = time.time()
    plan = pipeline.build_plan(g, order=args.order)
    t_plan = time.time() - t0

    # stream packed batches off the pipeline; spill oversize tiles to host
    t0 = time.time()
    batches = []
    spilled = []
    for item in pipeline.stream_batches(plan, args.k, order=args.order,
                                        batch_size=args.batch_size):
        (spilled if isinstance(item, tiles_mod.Tile) else batches).append(item)
    t_pack = time.time() - t0

    # each packed batch is one dispatch unit; LPT-balance them over devices
    device_bins, sched = schedule_batches(batches, l, n_dev)

    t0 = time.time()
    total = 0
    stats = Stats()
    for d, bin_ids in enumerate(device_bins):
        for bi in bin_ids:
            b = batches[bi]
            hard, nv, t, f = engine_jax.count_packed(
                jnp.asarray(b.A), jnp.asarray(b.cand), l,
                et=True, interpret=True)
            total += engine_jax.combine_counts(hard, nv, t, f, l, et=True)
    for tile in spilled:
        total += engine_jax.count_spilled(tile, args.order, l, stats,
                                          et_t=3, use_rule2=True)
    t_count = time.time() - t0

    n_tiles = sum(b.B for b in batches) + len(spilled)
    print(f"batches={len(batches)} tiles={n_tiles} "
          f"spilled={stats.spilled_tiles} devices={n_dev} "
          f"balance max/mean={sched['max_over_mean']:.3f}")
    print(f"k={args.k}: {total} cliques "
          f"(plan {t_plan:.2f}s, extract+pack {t_pack:.2f}s, "
          f"count {t_count:.2f}s)")
    if args.verify:
        ref = ebbkc.count(g, args.k, order=args.order, plan=plan).count
        print(f"host engine: {ref}  match={ref == total}")


if __name__ == "__main__":
    main()
