"""Distributed k-clique counting driver (the paper's operator as a service).

``python -m repro.launch.clique --graph rmat:14 --k 5``

Pipeline: host preprocessing (truss order + tile extraction + LPT
cost-balanced scheduling, Section 6.2(7) EdgeParallel) -> packed bitset
batches sharded over all mesh axes -> device kernels -> psum.
On this CPU container it runs on however many host devices exist; the
512-way layout is exercised by dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..core import ebbkc, engine_jax
from ..core.graph import Graph
from ..data import graphs as gdata
from ..runtime.clique_scheduler import schedule_tiles


def load_graph(desc: str) -> Graph:
    kind, _, arg = desc.partition(":")
    if kind == "rmat":
        return gdata.rmat_graph(int(arg or 12), edge_factor=8, seed=7)
    if kind == "er":
        n, p = arg.split(",")
        return gdata.erdos_renyi(int(n), float(p), seed=7)
    if kind == "powerlaw":
        return gdata.powerlaw_graph(int(arg or 2000), 16, seed=7)
    if kind == "planted":
        return gdata.planted_cliques(int(arg or 2000), 30, 12, seed=7)
    raise ValueError(f"unknown graph spec {desc}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--order", default="hybrid")
    ap.add_argument("--verify", action="store_true",
                    help="cross-check against the host engine")
    args = ap.parse_args()

    g = load_graph(args.graph)
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.time()
    binned = engine_jax.bin_tiles(g, args.k, order=args.order)
    t1 = time.time()
    total = 0
    l = args.k - 2
    n_dev = jax.device_count()
    for T, packed in binned.items():
        tiles_meta = [type("T", (), {"s": T, "nedges": T})()] \
            * packed.A.shape[0]
        _, stats = schedule_tiles(tiles_meta, l, n_dev)
        hard, nv, t, f = engine_jax.count_packed(
            jnp.asarray(packed.A), jnp.asarray(packed.cand), l,
            et=True, interpret=True)
        total += engine_jax.combine_counts(hard, nv, t, f, l, et=True)
        print(f"  bin T={T}: {packed.A.shape[0]} tiles, "
              f"balance max/mean={stats['max_over_mean']:.3f}")
    t2 = time.time()
    print(f"k={args.k}: {total} cliques "
          f"(extract {t1 - t0:.2f}s, count {t2 - t1:.2f}s)")
    if args.verify:
        ref = ebbkc.count(g, args.k, order=args.order).count
        print(f"host engine: {ref}  match={ref == total}")


if __name__ == "__main__":
    main()
