import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and record memory / cost / collective
analyses for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json (resumable: cells
with an existing artifact are skipped unless --force).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from .. import configs
from .mesh import make_production_mesh
from .roofline import collective_bytes, roofline_terms, model_flops_lm
from .steps import build_cell


def _measure(spec, shape, mesh):
    """Lower+compile one cell variant; return (flops, bytes, coll_bytes)."""
    cell = build_cell(spec, shape, mesh)
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_specs,
                     out_shardings=cell.out_specs)
    compiled = jitted.lower(*cell.abstract_args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(sum(v["operand_bytes"] for v in coll.values())))


def lm_probe_costs(spec, shape, mesh):
    """True per-step cost via unrolled small-depth probes.

    XLA's cost_analysis counts while-loop (scan) bodies ONCE, so the
    production scan-over-layers lowering under-reports FLOPs by ~n_layers.
    Cost is affine in the per-group layer counts: probe with unrolled
    models at counts (1,..), (2,1,..), (1,2,..), solve the affine model,
    extrapolate to the real depth.  (Discovered+validated in the first
    perf iteration -- EXPERIMENTS.md section Perf.)
    """
    cfg = spec.full
    groups = cfg.layer_groups
    G = len(groups)

    def probe_spec(counts):
        cfg_p = dataclasses.replace(
            cfg, analysis_unroll=True,
            groups_override=tuple((k, c) for (k, _), c
                                  in zip(groups, counts)))
        return dataclasses.replace(spec, full=cfg_p)

    base = _measure(probe_spec([1] * G), shape, mesh)
    slopes = []
    for i in range(G):
        counts = [2 if j == i else 1 for j in range(G)]
        got = _measure(probe_spec(counts), shape, mesh)
        slopes.append(tuple(g - b for g, b in zip(got, base)))
    out = []
    for t in range(3):  # flops, bytes, coll
        a = base[t] - sum(s[t] for s in slopes)
        val = a + sum(s[t] * c for s, (_, c) in zip(slopes, groups))
        out.append(max(val, 0.0))
    return {"flops": out[0], "bytes_accessed": out[1],
            "collective_operand_bytes": out[2],
            "probe_groups": [list(g) for g in groups]}


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             force: bool = False, verbose: bool = True) -> dict:
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    spec = configs.get(arch)
    cell_cfg = spec.cells[shape]
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "kind": cell_cfg.kind, "dims": cell_cfg.dims}
    if cell_cfg.skip:
        record.update(status="skipped", reason=cell_cfg.skip)
        _write(path, record)
        return record
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        t0 = time.time()
        cell = build_cell(spec, shape, mesh)
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_specs,
                         out_shardings=cell.out_specs)
        lowered = jitted.lower(*cell.abstract_args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # CPU backend may not support it
            mem["error"] = str(e)
        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            keep = ("flops", "bytes accessed", "transcendentals",
                    "optimal_seconds", "utilization")
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and k in keep}
        except Exception as e:
            cost["error"] = str(e)
        text = compiled.as_text()
        coll = collective_bytes(text)
        coll_total = sum(v["operand_bytes"] for v in coll.values())
        flops = cost.get("flops", 0.0)
        bytes_acc = cost.get("bytes accessed", 0.0)
        # LM models scan over layers; correct the once-counted loop bodies
        # via unrolled probes (affine in per-group depth)
        if spec.family == "lm":
            t3 = time.time()
            probe = lm_probe_costs(spec, shape, mesh)
            record["probe"] = probe
            record["probe_s"] = round(time.time() - t3, 3)
            flops = probe["flops"]
            bytes_acc = probe["bytes_accessed"]
            coll_total = probe["collective_operand_bytes"]
        terms = roofline_terms(max(flops, 0.0), max(bytes_acc, 0.0),
                               coll_total)
        record.update(
            status="ok",
            lower_s=round(t1 - t0, 3), compile_s=round(t2 - t1, 3),
            n_devices=len(mesh.devices.flat),
            memory=mem, cost=cost, collectives=coll,
            flops_per_device=flops, bytes_per_device=bytes_acc,
            collective_operand_bytes=coll_total,
            roofline=terms, meta=cell.meta,
        )
        if spec.family == "lm":
            mf = model_flops_lm(cell.meta, cell_cfg.kind)
            record["model_flops_global"] = mf
            n_dev = len(mesh.devices.flat)
            if flops > 0:
                record["model_over_hlo_flops"] = mf / (flops * n_dev)
    except Exception as e:
        record.update(status="error", error=str(e),
                      traceback=traceback.format_exc())
    _write(path, record)
    if verbose:
        stat = record["status"]
        extra = ""
        if stat == "ok":
            r = record["roofline"]
            extra = (f" compile={record['compile_s']}s"
                     f" flops/dev={record['cost'].get('flops', 0):.3e}"
                     f" dominant={r['dominant']}")
        print(f"[{mesh_name}] {arch}/{shape}: {stat}{extra}", flush=True)
    return record


def _write(path, record):
    with open(path + ".tmp", "w") as f:
        json.dump(record, f, indent=1, default=str)
    os.replace(path + ".tmp", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(configs.all_specs()) if args.arch == "all" else [args.arch]
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch in archs:
            spec = configs.get(arch)
            shapes = list(spec.cells) if args.shape == "all" \
                else [args.shape]
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_name, args.out, args.force)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"dry-run done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
