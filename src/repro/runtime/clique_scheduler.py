"""Straggler-aware scheduling of clique tiles onto devices.

The truss-based edge ordering is also a *load balancer*: every tile's cost
is bounded by tau, and the tile's work is predictable from its size before
dispatch (cost model below).  We over-decompose into ``overdecompose x
n_devices`` bins, assign greedily by Longest-Processing-Time (LPT), and
lay bins out round-robin so a slow device can shed whole bins on requeue.

Cost model (per tile, DFS kernel): branches ~ nedges * (s/4)^(l-3) for
l >= 3 capped crudely; calibrated against measured host-engine branch
counts in benchmarks/bench_parallel (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def tile_cost(s: int, nedges: int, l: int) -> float:
    return float(tile_costs(np.asarray([s]), np.asarray([nedges]), l)[0])


def tile_costs(s: np.ndarray, nedges: np.ndarray, l: int) -> np.ndarray:
    """Vectorized :func:`tile_cost` over the per-tile metadata arrays that
    :class:`repro.core.pipeline.TileBatch` carries (``sizes``/``nedges``)."""
    s = np.asarray(s, dtype=np.float64)
    e = np.asarray(nedges, dtype=np.float64)
    if l <= 1:
        return 1.0 + s
    if l == 2:
        return 1.0 + e
    expo = l - 3 if l > 3 else 0.5
    return 1.0 + e * np.maximum(1.0, s / 4.0) ** expo


def balanced_bins(costs: Sequence[float], n_bins: int
                  ) -> Tuple[List[List[int]], np.ndarray]:
    """LPT greedy: returns (bin -> tile indices, per-bin total cost)."""
    costs = np.asarray(costs, dtype=np.float64)
    order = np.argsort(-costs)
    loads = np.zeros(n_bins)
    bins: List[List[int]] = [[] for _ in range(n_bins)]
    for i in order:
        b = int(np.argmin(loads))
        bins[b].append(int(i))
        loads[b] += costs[i]
    return bins, loads


def schedule_tiles(tiles, l: int, n_devices: int, overdecompose: int = 16):
    """Returns (device -> tile ids, stats).

    ``tiles`` is either a list of objects with ``.s``/``.nedges`` or a
    :class:`repro.core.pipeline.TileBatch` (its ``sizes``/``nedges``
    metadata arrays are the cost-model inputs -- the batcher and the
    scheduler share one cost vocabulary).  Over-decomposition bounds the
    requeue unit for straggler mitigation while LPT keeps static balance
    tight (max/mean load reported).
    """
    if hasattr(tiles, "sizes") and hasattr(tiles, "nedges"):
        costs = tile_costs(tiles.sizes, tiles.nedges, l)
    else:
        costs = [tile_cost(t.s, t.nedges, l) for t in tiles]
    n_bins = max(1, min(len(costs), n_devices * overdecompose))
    bins, loads = balanced_bins(costs, n_bins)
    device_bins: List[List[int]] = [[] for _ in range(n_devices)]
    order = np.argsort(-loads)
    dev_loads = np.zeros(n_devices)
    for b in order:
        d = int(np.argmin(dev_loads))
        device_bins[d].extend(bins[b])
        dev_loads[d] += loads[b]
    stats = {
        "max_over_mean": float(dev_loads.max() / max(dev_loads.mean(), 1e-9)),
        "device_loads": dev_loads,
    }
    return device_bins, stats


def schedule_batches(batches: Sequence, l: int, n_devices: int
                     ) -> Tuple[List[List[int]], dict]:
    """LPT-assign whole packed batches to devices.

    ``batches``: sequence of :class:`repro.core.pipeline.TileBatch`.  Each
    batch is one dispatch unit (one fixed-shape device call), so device
    bins map one-to-one onto packed batches; a batch's cost is the sum of
    its per-tile cost-model terms.  Returns (device -> batch indices,
    stats with per-device loads and max/mean balance).
    """
    costs = [float(tile_costs(b.sizes, b.nedges, l).sum()) for b in batches]
    device_bins, loads = balanced_bins(costs, n_devices)
    stats = {
        "max_over_mean": float(loads.max() / max(loads.mean(), 1e-9)),
        "device_loads": loads,
        "batch_costs": np.asarray(costs),
    }
    return device_bins, stats
