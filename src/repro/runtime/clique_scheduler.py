"""Straggler-aware scheduling of clique tiles onto devices.

The truss-based edge ordering is also a *load balancer*: every tile's cost
is bounded by tau, and the tile's work is predictable from its size before
dispatch (cost model below).  We over-decompose into ``overdecompose x
n_devices`` bins, assign greedily by Longest-Processing-Time (LPT), and
lay bins out round-robin so a slow device can shed whole bins on requeue.

Cost model (per tile, DFS kernel): branches ~ nedges * (s/4)^(l-3) for
l >= 3 capped crudely; calibrated against measured host-engine branch
counts in benchmarks/bench_parallel (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def tile_cost(s: int, nedges: int, l: int) -> float:
    if l <= 1:
        return 1.0 + s
    if l == 2:
        return 1.0 + nedges
    return 1.0 + nedges * max(1.0, s / 4.0) ** (l - 3 if l > 3 else 0.5)


def balanced_bins(costs: Sequence[float], n_bins: int
                  ) -> Tuple[List[List[int]], np.ndarray]:
    """LPT greedy: returns (bin -> tile indices, per-bin total cost)."""
    costs = np.asarray(costs, dtype=np.float64)
    order = np.argsort(-costs)
    loads = np.zeros(n_bins)
    bins: List[List[int]] = [[] for _ in range(n_bins)]
    for i in order:
        b = int(np.argmin(loads))
        bins[b].append(int(i))
        loads[b] += costs[i]
    return bins, loads


def schedule_tiles(tiles, l: int, n_devices: int, overdecompose: int = 16):
    """tiles: list with .s and .nedges. Returns (device -> tile ids, stats).

    Over-decomposition bounds the requeue unit for straggler mitigation
    while LPT keeps static balance tight (max/mean load reported).
    """
    costs = [tile_cost(t.s, t.nedges, l) for t in tiles]
    n_bins = max(1, min(len(tiles), n_devices * overdecompose))
    bins, loads = balanced_bins(costs, n_bins)
    device_bins: List[List[int]] = [[] for _ in range(n_devices)]
    order = np.argsort(-loads)
    dev_loads = np.zeros(n_devices)
    for b in order:
        d = int(np.argmin(dev_loads))
        device_bins[d].extend(bins[b])
        dev_loads[d] += loads[b]
    stats = {
        "max_over_mean": float(dev_loads.max() / max(dev_loads.mean(), 1e-9)),
        "device_loads": dev_loads,
    }
    return device_bins, stats
