"""Fault-tolerant training loop.

* auto-resume from the newest committed checkpoint (params + optimizer +
  data-pipeline state), making restart-after-kill bitwise reproducible;
* periodic atomic checkpoints + GC;
* step-time watchdog: steps slower than ``watchdog_factor`` x the running
  median are logged as straggler events (at scale this feeds the controller
  that re-schedules the slow host);
* optional failure injection for tests (``fail_at_step``).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..checkpoint import (gc_checkpoints, restore_checkpoint,
                          save_checkpoint)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    watchdog_factor: float = 3.0
    fail_at_step: Optional[int] = None   # test hook: simulated crash


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, step_fn: Callable,
                 params, opt_state, pipeline):
        """step_fn(params, opt_state, batch) -> (params, opt_state, metrics)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.step = 0
        self.straggler_events = []
        self._times = []
        if cfg.checkpoint_dir:
            self._maybe_resume()

    def _maybe_resume(self):
        got = restore_checkpoint(self.cfg.checkpoint_dir,
                                 {"params": self.params,
                                  "opt": self.opt_state})
        if got is not None:
            self.params = got["tree"]["params"]
            self.opt_state = got["tree"]["opt"]
            self.pipeline.restore(got["pipeline"])
            self.step = got["step"]
            log.info("resumed from step %d", self.step)

    def _checkpoint(self):
        if not self.cfg.checkpoint_dir:
            return
        save_checkpoint(self.cfg.checkpoint_dir, self.step,
                        {"params": self.params, "opt": self.opt_state},
                        pipeline_state=self.pipeline.state())
        gc_checkpoints(self.cfg.checkpoint_dir, self.cfg.keep_checkpoints)

    def run(self) -> Dict[str, Any]:
        metrics = {}
        while self.step < self.cfg.total_steps:
            if self.cfg.fail_at_step is not None \
                    and self.step == self.cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {self.step}")
            batch = self.pipeline.next_batch()
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            dt = time.monotonic() - t0
            self._times.append(dt)
            med = float(np.median(self._times[-50:]))
            if len(self._times) > 5 and dt > self.cfg.watchdog_factor * med:
                self.straggler_events.append((self.step, dt, med))
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            self.step, dt, med)
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0:
                self._checkpoint()
        self._checkpoint()
        return {"final_step": self.step, "metrics": metrics,
                "stragglers": self.straggler_events}
