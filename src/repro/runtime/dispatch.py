"""Multi-device sharded dispatch for the streaming tile pipeline.

DESIGN.md section 4.  The edge-oriented branching of EBBkC makes the tile
stream embarrassingly parallel: every packed ``TileBatch`` is an independent
fixed-shape device call, so scaling past one chip is purely a placement and
staging problem.  This module turns the scheduler's LPT bins
(`clique_scheduler.schedule_batches`) into *real* devices:

* **Per-device dispatch** (default): each batch is committed to one local
  device with ``jax.device_put`` and counted by a per-device ``jit`` of
  ``engine_jax.count_packed`` (jit caches one executable per
  (shape, device) pair).  Placement is either *online LPT* -- each arriving
  batch goes to the least-loaded device under the scheduler cost model,
  which needs no lookahead and so composes with streaming -- or *offline
  LPT* via :func:`dispatch_scheduled`, which maps precomputed scheduler
  bins one-to-one onto devices.
* **shard_map path**: when the caller provides a mesh
  (``launch/mesh.py``), each batch is padded to the mesh batch axes and
  counted in a single SPMD step; outputs stay device-local and the host
  combines them exactly.
* **Double-buffered staging**: with ``async_staging=True`` (default) up to
  ``max_inflight`` batches per device are left un-harvested, so the host
  packs batch i+1 while the devices execute batch i.  The overlapped
  seconds are accounted in ``Stats.staging_overlap_s``.

Counts are exact and invariant to device count, placement, and staging
mode: every device step returns (hard, nv, t, f) partials and the host
reduces them in int64 (including the Section 5.1 early-termination closed
form), so a 1-device CPU CI run is byte-identical to an N-device run.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import functools
import itertools
import threading
import time
from typing import Deque, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from ..core import engine_jax, pipeline
from ..core.engine_np import Stats
from ..obs import profile as obs_profile
from ..obs import trace
from ..resilience import inject
from ..resilience import retry as fault_retry
from .clique_scheduler import schedule_batches, tile_costs

if hasattr(jax, "shard_map"):  # newer jax
    _shard_map = jax.shard_map
    _SHARD_MAP_CHECK = {"check_vma": False}
else:  # the pinned jax 0.4.37
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_CHECK = {"check_rep": False}


def resolve_devices(
    devices: Union[None, int, str, Sequence] = None,
) -> List[jax.Device]:
    """Normalize a ``devices=`` knob to a concrete local device list.

    ``None`` / ``"all"`` -> every local device; an int n -> the first
    min(n, available) devices (graceful CPU-CI fallback: asking for 4 on a
    1-device host degrades to 1 device, never errors); a sequence of jax
    devices is passed through.
    """
    avail = jax.devices()
    if devices is None or devices == "all":
        return list(avail)
    if isinstance(devices, int):
        if devices < 1:
            raise ValueError("devices must be >= 1")
        return list(avail[: min(devices, len(avail))])
    devs = list(devices)
    if not devs:
        raise ValueError("empty device list")
    return devs


def batch_flops(n_tiles: int, T: int) -> int:
    """MXU-equivalent flop model of one packed batch (dense-tile matmul)."""
    return int(n_tiles) * 2 * int(T) ** 3


def batch_bytes(n_tiles: int, T: int) -> int:
    """Bytes staged to a device per packed tile: the (T, W) uint32
    adjacency bitset plus the (W,) candidate mask (W = T/32).  The
    roofline bandwidth denominator paired with :func:`batch_flops`."""
    W = int(T) // 32
    return int(n_tiles) * (int(T) * W + W) * 4


def _account_devices(stats: Stats, per_device_tiles, T: int) -> None:
    """Fold one batch's per-device tile counts into ``stats``.

    The single accounting path shared by both dispatchers: builds a delta
    :class:`Stats` carrying only the per-device maps and folds it in via
    ``Stats.merge`` (the one merge routine -- see ``Stats._MERGE_KINDS``).
    """
    delta = Stats()
    for d, c in enumerate(per_device_tiles):
        if not c:
            continue
        delta.device_tiles[d] = int(c)
        delta.device_flops[d] = batch_flops(int(c), T)
        delta.device_bytes[d] = batch_bytes(int(c), T)
    stats.merge(delta)


def _mesh_batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes a tile batch shards over: every non-'model' axis of the mesh."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if axes else tuple(mesh.axis_names[:1])


# (step identity, input shape, device ordinal) triples whose first call --
# compile + first run -- has already been billed to kernel_compile_s; the
# jitted steps below are process-wide (lru_cache pins their identity), so
# the seen-set must be process-wide too
_COMPILED_STEPS = set()


@functools.lru_cache(maxsize=None)
def _device_step(
    l: int,
    method: str,
    et: bool,
    interpret: Optional[bool],
    backend: Optional[str] = None,
):
    """Process-wide jitted ``count_packed`` step, shared by all dispatchers.

    Memoized so repeated queries reuse one jit cache: jit compiles one
    executable per (input shape, device) pair, and a fresh ``jax.jit`` per
    dispatcher would re-trace the whole kernel on every query.
    """

    def step(A, cand):
        return engine_jax.count_packed(
            A, cand, l, method=method, et=et, interpret=interpret, backend=backend
        )

    return jax.jit(step)


@functools.lru_cache(maxsize=None)
def make_sharded_step(
    mesh: jax.sharding.Mesh,
    l: int,
    method: str = "auto",
    et: bool = True,
    interpret: Optional[bool] = None,
    backend: Optional[str] = None,
):
    """One jitted SPMD ``count_packed`` step over the mesh batch axes.

    Outputs keep the batch sharding (no psum): the host combines the
    per-shard partials exactly in int64, preserving the early-termination
    closed form.
    """
    P = jax.sharding.PartitionSpec
    axes = _mesh_batch_axes(mesh)

    def inner(A_loc, cand_loc):
        return engine_jax.count_packed(
            A_loc,
            cand_loc,
            l,
            method=method,
            et=et,
            interpret=interpret,
            backend=backend,
        )

    fn = _shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(axes, None, None), P(axes, None)),
        out_specs=(P(axes), P(axes), P(axes), P(axes)),
        **_SHARD_MAP_CHECK,
    )
    return jax.jit(fn), axes


def _pad_rows(x: np.ndarray, multiple: int) -> np.ndarray:
    """Zero-pad axis 0 of ``x`` up to a multiple of ``multiple``.

    Padding rows have ``cand == 0`` (no candidate vertices), which
    contributes exactly 0 to both the kernel and the closed-form count for
    every l >= 1, so padded and unpadded batches agree.
    """
    pad = (-x.shape[0]) % multiple
    if not pad:
        return x
    return np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])


@dataclasses.dataclass
class Routed:
    """One item of a multi-request stream: a pipeline item plus its route.

    Wrap ``pipeline.TileBatch`` / oversize ``Tile`` items in ``Routed``
    to interleave several logical requests through one dispatcher
    ``consume`` call.  ``route`` is forwarded verbatim: for a
    ``TileBatch`` it becomes the ``route=`` callback of ``submit`` (so
    this batch's results bypass the dispatcher-global accumulator/sink
    and are delivered to the owning request instead); for a spill tile it
    is passed as a second argument to ``on_spill``.  Bare (unwrapped)
    items keep the single-request behavior, so the two styles can mix in
    one stream.
    """

    item: object
    route: object = None


def _consume_stream(disp, stream, on_spill, stop=None) -> Tuple[int, int]:
    """Shared stream-consumption loop of both dispatchers' ``consume``.

    Submits packed batches, routes oversize spill tiles to ``on_spill``,
    and stops early when ``stop()`` turns true (the listing sink's
    ``full``).  Items may be wrapped in :class:`Routed` to tag them with
    a per-request route (multi-tenant streams); bare items behave as
    before.  Returns (tiles consumed, max tile width).
    """
    ntiles = 0
    max_tile = 0
    for item in stream:
        if stop is not None and stop():
            break
        route = None
        if isinstance(item, Routed):
            item, route = item.item, item.route
        if isinstance(item, pipeline.TileBatch):
            ntiles += item.B
            max_tile = max(max_tile, item.T)
            disp.submit(item, route=route)
            continue
        if on_spill is None:
            raise ValueError("oversize tile in stream but no on_spill "
                             "handler given")
        ntiles += 1
        max_tile = max(max_tile, item.s)
        if route is None:
            on_spill(item)
        else:
            on_spill(item, route)
    return ntiles, max_tile


@dataclasses.dataclass
class _InFlight:
    """One staged batch awaiting harvest (device arrays, not host data)."""

    device: int  # device ordinal; -1 for the shard_map path
    out: Tuple[jax.Array, jax.Array, jax.Array, jax.Array]
    rows: int = 0  # un-padded batch rows (slice bound for routed harvest)
    route: object = None  # per-request delivery callback, or None
    T: int = 0  # tile width (profiling attribution)
    batch: object = None  # host TileBatch, kept for resilient re-execution


class Dispatcher:
    """Streams packed tile batches across the local device set.

    See the module docstring for the execution model.  Typical use::

        disp = Dispatcher(l, devices="all", stats=stats)
        for item in pipeline.stream_batches(plan, k):
            if isinstance(item, pipeline.TileBatch):
                disp.submit(item)
            else:
                ...  # spill to host recursion
        total = disp.finish()
    """

    def __init__(
        self,
        l: int,
        devices: Union[None, int, str, Sequence] = None,
        *,
        mesh: Optional[jax.sharding.Mesh] = None,
        et: bool = True,
        method: str = "auto",
        interpret: Optional[bool] = None,
        backend: Optional[str] = None,
        async_staging: bool = True,
        max_inflight: int = 2,
        stats: Optional[Stats] = None,
        stage_times: Optional[dict] = None,
        retry_policy: Optional[fault_retry.RetryPolicy] = None,
    ):
        from ..kernels import ops as kops

        if l < 1:
            raise ValueError("dispatch requires l >= 1 (k >= 3)")
        self.l = l
        self.et = et
        self.mesh = mesh
        self.async_staging = async_staging
        self.max_inflight = max(1, int(max_inflight))
        self.stats = stats if stats is not None else Stats()
        # resolve once and bake the *resolved* name into the cached step:
        # the jit cache key must distinguish REPRO_BACKEND states, and what
        # actually executes must match what stats.backend reports
        backend = kops.resolve_backend(backend, interpret)
        self.stats.backend = backend
        self.stage_times = stage_times
        self.retry_policy = retry_policy or fault_retry.DEFAULT_POLICY
        # kept for building demoted steps down the backend ladder
        self._method = method
        self._interpret = interpret
        self._backend = backend
        self.total = 0
        self.tiles = 0
        self.placements: List[int] = []
        self._inflight: Deque[_InFlight] = collections.deque()
        self._overlap_mark = 0.0
        if mesh is not None:
            self.devices = list(mesh.devices.flat)
            self._step, axes = make_sharded_step(
                mesh, l, method=method, et=et, interpret=interpret, backend=backend
            )
            self._n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            ns, ps = jax.sharding.NamedSharding, jax.sharding.PartitionSpec
            self._in_shardings = (
                ns(mesh, ps(axes, None, None)),
                ns(mesh, ps(axes, None)),
            )
        else:
            self.devices = resolve_devices(devices)
            self._n_shards = 1
            self._in_shardings = None
            self._step = _device_step(l, method, et, interpret, backend)
        self._loads = np.zeros(len(self.devices))

    def _run_step(self, A, cand, device: int, step=None):
        """Invoke the jitted step; time the first call per
        (step, shape, device) signature into ``stats.kernel_compile_s``
        (compile + first run).  The seen-set is process-wide, matching the
        process-wide jit cache: a warm executable must neither block nor
        re-bill its run time as compile on later dispatcher instances.
        ``step`` overrides the baked-in step (demoted-backend retries)."""
        step = self._step if step is None else step
        sig = (id(step), A.shape, device)
        if sig in _COMPILED_STEPS:
            return step(A, cand)
        t0 = time.perf_counter()
        with trace.span("kernel/compile", sig=self._sig(A.shape[0], A.shape[1])):
            out = jax.block_until_ready(step(A, cand))
        dt = time.perf_counter() - t0
        self.stats.kernel_compile_s += dt
        obs_profile.note_kernel(self._sig(A.shape[0], A.shape[1]), compile_s=dt)
        _COMPILED_STEPS.add(sig)
        return out

    def _sig(self, B: int, T: int) -> str:
        """Kernel-signature label for profiling attribution."""
        return f"count[l={self.l},T={T},B={B},backend={self.stats.backend}]"

    @property
    def n_devices(self) -> int:
        """Number of devices this dispatcher places batches on."""
        return len(self.devices)

    def _account(self, per_device_tiles: np.ndarray, T: int) -> None:
        _account_devices(self.stats, per_device_tiles, T)

    def _note_retry(self, attempt: int, exc: BaseException) -> None:
        """Per-batch attempt accounting hook (``retry.call`` on_retry)."""
        self.stats.retries += 1
        trace.instant("resilience/retry", attempt=attempt,
                      error=type(exc).__name__)

    def _launch_on(self, batch: pipeline.TileBatch, d: int,
                   backend: Optional[str]):
        """Stage ``batch`` on device ``d`` and launch one count step.

        ``backend=None`` uses the dispatcher's baked-in step; a backend
        name builds (and jit-caches) the demoted step for that rung.
        Fires the ``device.stage`` and ``kernel.launch`` fault sites.
        """
        if backend is None and self.mesh is None:
            step = None
        else:
            step = _device_step(self.l, self._method, self.et,
                                self._interpret, backend or self._backend)
        inject.fire("device.stage")
        # batch-shape bucketing: ragged tail chunks pad to pow2 and reuse
        # the full chunks' executables (padding counts 0)
        A = jax.device_put(engine_jax.bucket_rows(batch.A), self.devices[d])
        cand = jax.device_put(engine_jax.bucket_rows(batch.cand),
                              self.devices[d])
        inject.fire("kernel.launch")
        return self._run_step(A, cand, d, step=step)

    def _launch(self, batch: pipeline.TileBatch, d: int, *,
                block: bool = False):
        """Launch with retry, then demotion down the backend ladder.

        Each rung (the resolved backend, then its ``fault_retry.demote``
        successors: pallas -> lax -> ref) is retried under
        ``retry_policy``; an exhausted ladder falls back to the host
        recursion (:meth:`_host_partials`), which cannot fail.  Every
        rung returns exact partials, so retried and demoted batches stay
        byte-identical to a fault-free run.  ``block=True`` additionally
        waits for the result (harvest-side recovery re-entering the same
        FIFO slot).
        """
        backend = None  # None = the dispatcher's resolved backend
        while True:
            try:
                out = fault_retry.call(
                    lambda b=backend: self._launch_on(batch, d, b),
                    policy=self.retry_policy, retry_on=(Exception,),
                    token="count.launch", on_retry=self._note_retry)
                if block:
                    jax.block_until_ready(out)
                return out
            except Exception as exc:
                self.stats.demotions += 1
                nxt = fault_retry.demote(
                    "count", backend if backend is not None else self._backend)
                trace.instant("resilience/demote", frm=backend or self._backend,
                              to=nxt or "host", error=type(exc).__name__)
                if nxt is None:
                    return self._host_partials(batch)
                backend = nxt

    def _host_partials(self, batch: pipeline.TileBatch):
        """Count ``batch`` on the host recursion (the ladder's last rung).

        Returns numpy ``(hard, nv, t, f)`` partials that
        ``engine_jax.combine_counts`` finishes to the exact same totals
        as a device step: ``hard`` carries the true per-tile count and
        ``t`` is pinned above the 2-plex threshold, so the
        early-termination closed form adds nothing.
        """
        from ..core import listing
        from ..core.engine_np import count_rec_C

        hard = np.zeros(batch.B, dtype=np.int64)
        for b in range(batch.B):
            s = int(batch.sizes[b])
            rows = listing._rows_from_packed(batch.A[b], s)
            hard[b] = count_rec_C(rows, (1 << s) - 1, self.l, self.stats)
        zeros = np.zeros(batch.B, dtype=np.int64)
        return hard, zeros, np.full(batch.B, 3, dtype=np.int64), zeros

    def submit(
        self,
        batch: pipeline.TileBatch,
        device: Optional[int] = None,
        route=None,
    ) -> None:
        """Stage one packed batch and launch its device step (non-blocking).

        ``device`` forces a placement (offline scheduling); otherwise the
        batch goes to the least-loaded device under the scheduler cost
        model (online LPT).

        ``route``, when given, redirects this batch's results: at harvest
        the raw per-tile partials are fetched, sliced back to the batch's
        un-padded ``B`` rows, and passed to ``route(hard, nv, t, f)`` as
        int64 numpy arrays instead of being folded into ``self.total``
        (use ``engine_jax.combine_counts`` on any row segment to finish
        them exactly).  This is the multi-tenant seam: batches from
        different requests share devices and warm executables while their
        counts route back to their owners.  Routes run on the thread that
        triggers the harvest (the submitting/draining thread).

        Thread safety: all ``submit``/``drain``/``finish`` calls must come
        from one thread; only the ``route`` callbacks themselves may hand
        work to other threads.

        Resilience: a failed stage/launch is retried under
        ``retry_policy``, then demoted down the backend ladder
        (:meth:`_launch`); the batch keeps its FIFO position either way.
        """
        with trace.span("device/stage", B=batch.B, T=batch.T):
            if self.mesh is not None:
                d = -1

                def launch_mesh():
                    inject.fire("device.stage")
                    A = _pad_rows(batch.A, self._n_shards)
                    cand = _pad_rows(batch.cand, self._n_shards)
                    A, cand = jax.device_put((A, cand), self._in_shardings)
                    inject.fire("kernel.launch")
                    return A.shape[0], self._run_step(A, cand, d)

                try:
                    padded, out = fault_retry.call(
                        launch_mesh, policy=self.retry_policy,
                        retry_on=(Exception,), token="count.mesh",
                        on_retry=self._note_retry)
                except Exception:
                    # the SPMD step has no per-device ladder; fall straight
                    # back to the (exact) host recursion
                    self.stats.demotions += 1
                    padded, out = batch.B, self._host_partials(batch)
                shard_rows = max(1, padded // self._n_shards)
                per_dev = np.bincount(
                    np.minimum(np.arange(batch.B) // shard_rows, self._n_shards - 1),
                    minlength=self._n_shards,
                )
            else:
                d = int(np.argmin(self._loads)) if device is None else int(device)
                cost = float(tile_costs(batch.sizes, batch.nedges, self.l).sum())
                self._loads[d] += cost
                out = self._launch(batch, d)
                per_dev = np.zeros(self.n_devices, dtype=np.int64)
                per_dev[d] = batch.B
        self.placements.append(d)
        self.tiles += batch.B
        self._account(per_dev, batch.T)
        if not self._inflight:
            # in-flight window (re)opens now; overlap accrues from here
            self._overlap_mark = time.perf_counter()
        self._inflight.append(_InFlight(d, out, batch.B, route, batch.T, batch))
        if not self.async_staging:
            self._drain()
        else:
            while len(self._inflight) > self.max_inflight * self.n_devices:
                self._harvest_one()

    def _harvest_one(self) -> None:
        p = self._inflight.popleft()
        t0 = time.perf_counter()
        # wall time since the last accounting mark during which work was in
        # flight and the host was free (packing / combining, not blocked):
        # an upper bound on the device execution hidden behind host work
        # (the device may have finished early; measuring true device busy
        # time would need device-side profiling).  Counting whole
        # dispatch-to-harvest residencies instead would double-count
        # concurrent in-flight batches.  Synchronous staging hides nothing
        # by construction.
        if self.async_staging:
            self.stats.staging_overlap_s += max(0.0, t0 - self._overlap_mark)
        B = int(p.out[0].shape[0])
        rows = p.rows or B
        with trace.span(
            "device/harvest",
            device=p.device,
            sig=self._sig(B, p.T),
            flops=batch_flops(rows, p.T),
            bytes=batch_bytes(rows, p.T),
        ):
            # injected harvest faults are pure (the device result still
            # exists) and absorbed in place; a REAL wait failure means the
            # staged result is lost -- recompute the same batch
            # synchronously in its FIFO slot, so totals and routed
            # partials are unchanged
            fault_retry.consume("device.harvest", on_retry=self._note_retry)
            try:
                jax.block_until_ready(p.out)
            except Exception as exc:
                self._note_retry(1, exc)
                p.out = self._launch(p.batch, max(p.device, 0), block=True)
                B = int(p.out[0].shape[0])
        t1 = time.perf_counter()
        obs_profile.note_kernel(
            self._sig(B, p.T),
            execute_s=t1 - t0,
            calls=1,
            flops=batch_flops(rows, p.T),
            nbytes=batch_bytes(rows, p.T),
        )
        self._overlap_mark = t1  # blocked interval [t0, t1] is not overlap
        with trace.span("combine", routed=p.route is not None):
            if p.route is None:
                self.total += engine_jax.combine_counts(*p.out, self.l, self.et)
            else:
                # multi-tenant: hand the un-padded partial rows to the owner
                # (shape padding appends rows, so a head slice removes it)
                p.route(*(np.asarray(x)[: p.rows] for x in p.out))
        t2 = time.perf_counter()
        if self.stage_times is not None:
            st = self.stage_times
            st["device"] = st.get("device", 0.0) + (t1 - t0)
            st["combine"] = st.get("combine", 0.0) + (t2 - t1)

    def _drain(self) -> None:
        while self._inflight:
            self._harvest_one()

    def consume(self, stream, on_spill=None) -> Tuple[int, int]:
        """Drive this dispatcher from a ``pipeline.stream_batches`` iterator.

        The single consumption point shared by counting and listing: both
        engines hand the dispatcher the (possibly parallel-producer)
        stream and the dispatcher pulls from its bounded prefetch queue,
        submitting packed batches and routing oversize spill tiles to
        ``on_spill``.  The stream may interleave several requests by
        wrapping items in :class:`Routed` -- routed batches deliver their
        partials to their own route callback instead of ``self.total``,
        and routed spills call ``on_spill(tile, route)``.  Returns
        (tiles consumed, max tile width); call :meth:`finish` (one-shot)
        or :meth:`drain` (long-lived service) afterwards.
        """
        return _consume_stream(self, stream, on_spill)

    def drain(self) -> None:
        """Block until every submitted batch is harvested (routes included).

        The long-lived-service twin of :meth:`finish`: it flushes the
        in-flight window without touching the backend compile/tune
        accounting, so the dispatcher stays usable for further
        ``submit`` calls.
        """
        self._drain()

    def finish(self) -> int:
        """Drain all in-flight work; returns the accumulated exact count.

        Routed batches are not part of the returned total -- their counts
        went to their route callbacks.
        """
        from ..kernels import ops as kops

        self._drain()
        self.stats.kernel_compile_s += kops.consume_compile_s()
        kops.drain_tune_events(self.stats)
        return self.total


#: initial emit-buffer rows for the speculative capacity ratchet (pow2;
#: small enough that a wrong first guess wastes little, large enough that
#: sparse tile batches never retry)
SPECULATIVE_CAP0 = 64


def _is_ready(x) -> bool:
    """Non-blocking readiness probe of a device array (True = safe to
    fetch without stalling).  Conservatively True when the runtime lacks
    ``is_ready`` -- callers then simply block, the pre-overlap behavior."""
    try:
        return bool(x.is_ready())
    except AttributeError:  # pragma: no cover - older jax runtimes
        return True


class ListDispatcher:
    """Emit-mode twin of :class:`Dispatcher` for the listing subsystem.

    Streams packed tile batches across the local device set and harvests
    (count, overflow, buffer) triples instead of scalar partials.  Three
    capacity modes size the per-tile emit buffer:

    * ``capacity=None`` / ``"sized"`` (default) -- exact per-batch sizing
      by a pipelined count pass: ``submit`` launches the count pass
      asynchronously and queues the batch as *pending*; the listing
      kernel is launched as soon as that batch's counts land on the host
      (probed non-blockingly via ``jax.Array.is_ready`` each submit, or
      forced when the in-flight window fills).  Minimal buffer memory,
      two device passes.
    * ``capacity="speculative"`` -- the listing kernel launches
      immediately at a per-tile-width capacity ratchet (the pow2 ceiling
      of every true count seen so far for that T, starting at
      ``SPECULATIVE_CAP0``).  The kernel always returns true counts, so a
      guess that proves too small is retried once on the device at the
      exact pow2 size (``Stats.emit_retries``) -- the answer is
      identical, only the work moves.  One device pass per batch instead
      of two, but the buffer rides in the DFS ``while_loop`` carry, so an
      over-ratcheted capacity taxes every loop iteration -- measured
      slower than "sized" on the lax/CPU backend, hence opt-in.
    * ``capacity=<int>`` -- pinned buffer; overflowed tiles re-list on
      the host (never truncated), as always.

    Harvest/decode of completed triples overlaps device execution of
    later batches in every mode.

    Ordering guarantee: pending batches are promoted strictly FIFO,
    harvested strictly FIFO, and decoded/emitted by **one** decode-worker
    thread consuming a FIFO queue, so decoded rows reach the sink
    deterministically **in batch order** no matter how many devices
    executed them or how staging overlapped (asserted by
    ``tests/test_dispatch.py::test_list_dispatcher_sink_order_deterministic``
    and stress-tested under adversarial readiness schedules in
    ``tests/test_determinism.py``).  The decode worker also owns the
    blocking wait for each device triple, so decode, overflow re-lists,
    and sink writes all overlap both device execution and the consumer
    thread's submit/promote work; its backlog is bounded
    (``max_inflight * n_devices`` jobs) because each job pins its device
    buffers.  Overflowed tiles are re-listed on the host at decode time
    (never truncated); the shard_map mesh path is counting-only.
    """

    def __init__(
        self,
        l: int,
        devices: Union[None, int, str, Sequence] = None,
        *,
        sink=None,
        stats: Optional[Stats] = None,
        capacity: Optional[int] = None,
        max_capacity: Optional[int] = None,
        cap_policy: str = "pow2",
        et_t: int = 3,
        interpret: Optional[bool] = None,
        backend: Optional[str] = None,
        async_staging: bool = True,
        max_inflight: int = 2,
        stage_times: Optional[dict] = None,
        retry_policy: Optional[fault_retry.RetryPolicy] = None,
    ):
        from ..core import listing
        from ..kernels import ops as kops

        if l < 1:
            raise ValueError("dispatch requires l >= 1 (k >= 3)")
        if isinstance(capacity, str) and capacity not in ("sized",
                                                          "speculative"):
            raise ValueError(f"capacity must be None, 'sized', "
                             f"'speculative', or an int, got {capacity!r}")
        self.l = l
        self.sink = sink
        self.stats = stats if stats is not None else Stats()
        # resolved once, like Dispatcher: cached step and stats must agree
        backend = kops.resolve_backend(backend, interpret)
        self.stats.backend = backend
        self.capacity = capacity
        self.max_capacity = (
            listing.MAX_CAPACITY if max_capacity is None else int(max_capacity)
        )
        self.cap_policy = cap_policy  # emit-buffer rounding (tuned knob)
        # speculative mode: pow2 capacity ratchet per tile width.  Written
        # by the decode worker (true counts), read by submit; a stale read
        # is harmless -- it only costs one device retry.
        self._cap_ratchet: dict = {}
        self.et_t = et_t
        self.interpret = interpret
        self.backend = backend
        self.retry_policy = retry_policy or fault_retry.DEFAULT_POLICY
        self.async_staging = async_staging
        self.max_inflight = max(1, int(max_inflight))
        self.stage_times = stage_times
        self.tiles = 0
        self.placements: List[int] = []
        self.devices = resolve_devices(devices)
        # et=False: ``hard`` is then the raw per-tile count for EVERY tile
        # (no 2-plex masking), which is exactly the emit-buffer size input
        self._count_step = _device_step(l, "auto", False, interpret, backend)
        self._loads = np.zeros(len(self.devices))
        # count pass in flight, list kernel not yet launched (FIFO)
        self._pending: Deque[Tuple[int, pipeline.TileBatch, tuple]] = (
            collections.deque()
        )
        # list kernel in flight, not yet harvested (FIFO)
        self._inflight: Deque[Tuple[int, pipeline.TileBatch, tuple]] = (
            collections.deque()
        )
        # ONE decode worker: harvest hands (batch, triple) jobs to it, so
        # blocking on device results, decoding, overflow re-lists, and
        # sink emission all overlap the consumer thread's submit/promote
        # work -- and a single worker consuming a FIFO queue preserves
        # the deterministic sink order by construction
        self._decode_ex = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="emit-decode"
        )
        self._decoding: Deque[concurrent.futures.Future] = collections.deque()
        self._decode_depth = max(2, self.max_inflight * len(self.devices))
        # stats/stage_times are written by both the consumer thread
        # (sizing waits) and the decode worker (decode/emit seconds)
        self._acct_lock = threading.Lock()

    @property
    def n_devices(self) -> int:
        """Number of devices this dispatcher places batches on."""
        return len(self.devices)

    def _note_retry(self, attempt: int, exc: BaseException) -> None:
        """Per-batch attempt accounting (submit thread + decode worker)."""
        with self._acct_lock:
            self.stats.retries += 1
        trace.instant("resilience/retry", attempt=attempt,
                      error=type(exc).__name__)

    def _note_demotion(self, frm: Optional[str], to: Optional[str]) -> None:
        """Count one rung of the backend ladder in Stats/trace."""
        with self._acct_lock:
            self.stats.demotions += 1
        trace.instant("resilience/demote", frm=frm or self.backend,
                      to=to or "host")

    def _stage(self, batch: pipeline.TileBatch, d: int):
        """Fire the stage site and device_put the bucketing-padded batch.

        The padded zero-candidate lanes are sliced off again in the
        decode job (padding rows count 0 and never overflow).
        """
        inject.fire("device.stage")
        A = jax.device_put(engine_jax.bucket_rows(batch.A), self.devices[d])
        cand = jax.device_put(engine_jax.bucket_rows(batch.cand),
                              self.devices[d])
        return A, cand

    def _count_pass(self, A, cand):
        """Fire the launch site and start the async sizing count pass."""
        inject.fire("kernel.launch")
        return self._count_step(A, cand)[0]

    def _list_once(self, A, cand, cap: int, backend: str):
        """Fire the launch site and start one list kernel."""
        from ..kernels import ops as kops

        inject.fire("kernel.launch")
        return kops.list_tiles(A, cand, self.l, capacity=cap,
                               backend=backend, interpret=self.interpret)

    def _launch_list(self, batch: pipeline.TileBatch, A, cand, cap: int):
        """Launch one list kernel with retry, then backend demotion.

        Rungs: the resolved backend, then its ``fault_retry.demote``
        successor (pallas -> lax; ``ref`` implements counting only).  An
        exhausted ladder falls back to ``listing.host_list_triple`` --
        the host recursion in kernel emission order -- so the returned
        triple decodes byte-identically no matter which rung served it.
        """
        from ..core import listing

        backend = self.backend
        while True:
            try:
                return fault_retry.call(
                    lambda b=backend: self._list_once(A, cand, cap, b),
                    policy=self.retry_policy, retry_on=(Exception,),
                    token="list.launch", on_retry=self._note_retry)
            except Exception:
                nxt = fault_retry.demote("list", backend)
                self._note_demotion(backend, nxt)
                if nxt is None:
                    return listing.host_list_triple(batch, self.l)
                backend = nxt

    def _relaunch_sync(self, batch: pipeline.TileBatch, cap: int):
        """Harvest-side recovery: re-stage and re-list a lost batch.

        Returns a triple (device or host) for the same FIFO slot; never
        raises -- a dead device falls through to the host recursion.
        """
        try:
            A = jax.device_put(engine_jax.bucket_rows(batch.A),
                               self.devices[0])
            cand = jax.device_put(engine_jax.bucket_rows(batch.cand),
                                  self.devices[0])
        except Exception:
            from ..core import listing

            self._note_demotion(self.backend, None)
            return listing.host_list_triple(batch, self.l)
        return self._launch_list(batch, A, cand, cap)

    def submit(
        self,
        batch: pipeline.TileBatch,
        device: Optional[int] = None,
        route=None,
    ) -> None:
        """Stage one batch and launch its (first) device pass.

        ``device`` forces a placement (offline scheduling); otherwise
        online LPT picks the least-loaded device.

        ``route``, when given, replaces the default decode-and-emit for
        this batch: on the decode worker, ``route(batch, bufs, cnt,
        ovf)`` receives the raw listing triple sliced back to the batch's
        un-padded ``B`` rows and must return the number of rows it
        emitted (use ``listing.decode_batch`` on any row segment to
        materialize them).  Routed batches never touch ``self.sink``
        (which may then be None) -- this is the multi-tenant seam:
        batches fused from several requests run as one device call while
        each request's rows reach its own sink.  Route callbacks run on
        the single decode worker in strict FIFO batch order, so the
        per-request delivery order is as deterministic as the default
        sink path.

        Thread safety: all ``submit``/``drain``/``finish`` calls must
        come from one thread; routes run on the decode worker thread.

        Resilience: staging and launches are retried under
        ``retry_policy`` and demoted down the listing backend ladder
        (pallas -> lax -> ``listing.host_list_triple``); a batch keeps
        its FIFO queue position whichever rung serves it, so the decoded
        row stream is byte-identical to a fault-free run.
        """
        from ..core import listing

        if route is None and self.sink is None:
            raise ValueError("emit mode requires a CliqueSink (or per-"
                             "batch route callbacks)")
        with trace.span("device/stage", B=batch.B, T=batch.T):
            d = int(np.argmin(self._loads)) if device is None else int(device)
            cost = float(tile_costs(batch.sizes, batch.nedges, self.l).sum())
            self._loads[d] += cost
            self.placements.append(d)
            self.tiles += batch.B
            per_dev = np.zeros(self.n_devices, dtype=np.int64)
            per_dev[d] = batch.B
            with self._acct_lock:
                _account_devices(self.stats, per_dev, batch.T)
            try:
                A, cand = fault_retry.call(
                    lambda: self._stage(batch, d), policy=self.retry_policy,
                    retry_on=(Exception,), token="list.stage",
                    on_retry=self._note_retry)
            except Exception:
                A = cand = None
                self._note_demotion(self.backend, None)
            sized = self.capacity is None or self.capacity == "sized"
            if sized:
                hard = None
                if A is not None:
                    try:
                        # async count pass; readiness is probed at
                        # promotion time
                        hard = fault_retry.call(
                            lambda: self._count_pass(A, cand),
                            policy=self.retry_policy, retry_on=(Exception,),
                            token="list.sizing", on_retry=self._note_retry)
                    except Exception:
                        # sizing rung dead: the whole batch is listed on
                        # the host at promotion time (keeps FIFO order)
                        self._note_demotion(self.backend, None)
                self._pending.append((d, batch, (A, cand, hard), route))
            else:
                if self.capacity == "speculative":  # ratchet guess
                    cap = min(self._cap_ratchet.get(batch.T, SPECULATIVE_CAP0),
                              self.max_capacity)
                else:
                    cap = max(1, int(self.capacity))
                if A is None:
                    out = listing.host_list_triple(batch, self.l)
                else:
                    out = self._launch_list(batch, A, cand, cap)
                self._inflight.append((d, batch, (A, cand), out, route))
        self._promote(block=False)
        if not self.async_staging:
            self._drain()
        else:
            while (
                len(self._pending) + len(self._inflight)
                > self.max_inflight * self.n_devices
            ):
                self._harvest_one()

    def _promote(self, block: bool) -> None:
        """Launch list kernels for pending count-sized batches, strictly
        FIFO (``capacity="sized"`` mode only; the other modes launch in
        ``submit``).

        With ``block=False`` only batches whose count pass already landed
        are promoted; ``block=True`` forces at least the queue head
        through (used when the harvest side runs dry).
        """
        from ..core import listing

        while self._pending:
            d, batch, (A, cand, hard), route = self._pending[0]
            if hard is None:
                # sizing (or staging) already exhausted its ladder in
                # submit: list the batch on the host, keeping FIFO order
                self._pending.popleft()
                out = listing.host_list_triple(batch, self.l)
                self._inflight.append((d, batch, (A, cand), out, route))
                block = False
                continue
            if not block and not _is_ready(hard):
                break
            t0 = time.perf_counter()
            counts = None
            with trace.span("device/sizing", B=batch.B, T=batch.T):
                try:
                    counts = np.asarray(hard)  # blocks only until THIS batch
                except Exception as exc:
                    # count pass lost in flight: host-list the whole batch
                    self._note_retry(1, exc)
                    self._note_demotion(self.backend, None)
            if self.stage_times is not None:
                with self._acct_lock:
                    self.stage_times["device"] = (
                        self.stage_times.get("device", 0.0)
                        + time.perf_counter() - t0
                    )
            self._pending.popleft()
            if counts is None:
                out = listing.host_list_triple(batch, self.l)
            else:
                cap = listing.capacity_for(
                    counts, self.max_capacity, policy=self.cap_policy
                )
                out = self._launch_list(batch, A, cand, cap)
            self._inflight.append((d, batch, (A, cand), out, route))
            block = False  # only the head is ever forced

    def _decode_job(self, batch: pipeline.TileBatch, acand: tuple,
                    out: tuple, route=None) -> None:
        """Run one decode job on the decode worker.

        Blocks for the device triple, then either decodes to global rows
        (incl. overflow re-lists) and feeds the sink, or -- for routed
        batches -- hands the sliced triple to the owning request's
        ``route``.  Only this thread ever touches the sink or
        ``emitted_cliques`` / ``overflowed_tiles``, so FIFO submission ==
        deterministic sink order with no further synchronization.

        Resilience: injected harvest faults are absorbed in place; a real
        fetch failure (the triple was lost after launch) re-lists the
        same batch synchronously in its FIFO slot, demoting down the
        ladder to the kernel-order host recursion if needed -- so the
        decoded rows never change."""
        from ..core import listing

        t0 = time.perf_counter()
        sig = (f"list[l={self.l},T={batch.T},B={batch.B},"
               f"backend={self.backend}]")
        # slice off the bucketing padding (zero-candidate lanes) before
        # ratchet/decode -- padding rows count 0 and never overflow
        relaunched = False
        with trace.span(
            "device/wait",
            sig=sig,
            flops=batch_flops(batch.B, batch.T),
            bytes=batch_bytes(batch.B, batch.T),
        ):
            fault_retry.consume("device.harvest", on_retry=self._note_retry)
            try:
                bufs, cnt, ovf = (np.asarray(x)[: batch.B] for x in out)
            except Exception as exc:
                self._note_retry(1, exc)
                relaunched = True
                cap = min(self._cap_ratchet.get(batch.T, SPECULATIVE_CAP0),
                          self.max_capacity)
                out2 = self._relaunch_sync(batch, cap)
                try:
                    bufs, cnt, ovf = (np.asarray(x)[: batch.B] for x in out2)
                except Exception:
                    self._note_demotion(self.backend, None)
                    bufs, cnt, ovf = listing.host_list_triple(batch, self.l)
        if self.capacity == "speculative" or relaunched:
            # the kernel reported true counts, so a too-small guess is
            # retried once on the device at the exact rounded size --
            # identical triples, never a host re-list unless the true
            # count exceeds max_capacity (as in every mode)
            true_cap = listing.capacity_for(
                cnt, self.max_capacity, policy=self.cap_policy
            )
            self._cap_ratchet[batch.T] = max(
                self._cap_ratchet.get(batch.T, 1), true_cap
            )
            if ovf.any() and true_cap > bufs.shape[1]:
                A, cand = acand
                with trace.span("device/relist", B=batch.B, T=batch.T,
                                capacity=true_cap):
                    if relaunched:
                        out2 = self._relaunch_sync(batch, true_cap)
                    else:
                        out2 = self._launch_list(batch, A, cand, true_cap)
                    try:
                        bufs, cnt, ovf = (
                            np.asarray(x)[: batch.B] for x in out2
                        )
                    except Exception as exc:
                        self._note_retry(1, exc)
                        self._note_demotion(self.backend, None)
                        bufs, cnt, ovf = listing.host_list_triple(
                            batch, self.l
                        )
                with self._acct_lock:
                    self.stats.emit_retries += 1
        t1 = time.perf_counter()
        obs_profile.note_kernel(
            sig,
            execute_s=t1 - t0,
            calls=1,
            flops=batch_flops(batch.B, batch.T),
            nbytes=batch_bytes(batch.B, batch.T),
        )
        fault_retry.consume("decode", on_retry=self._note_retry)
        with trace.span("decode", B=batch.B, T=batch.T,
                        routed=route is not None):
            if route is not None:
                emitted = int(route(batch, bufs, cnt, ovf))
            else:
                arr = listing.decode_batch(
                    batch, bufs, cnt, ovf, self.l, self.stats, et_t=self.et_t
                )
                fault_retry.consume("sink.write", on_retry=self._note_retry)
                emitted = self.sink.emit(arr)
        t2 = time.perf_counter()
        with self._acct_lock:
            self.stats.emitted_cliques += emitted
            if self.stage_times is not None:
                st = self.stage_times
                st["device"] = st.get("device", 0.0) + (t1 - t0)
                st["emit"] = st.get("emit", 0.0) + (t2 - t1)

    def emit_rows(self, arr: np.ndarray) -> None:
        """Enqueue host-produced rows (spill tiles) through the decode
        worker, keeping their FIFO position relative to batch decodes."""

        def job() -> None:
            fault_retry.consume("sink.write", on_retry=self._note_retry)
            emitted = self.sink.emit(arr)
            with self._acct_lock:
                self.stats.emitted_cliques += emitted

        self._decoding.append(self._decode_ex.submit(job))

    def _harvest_one(self) -> None:
        if not self._inflight:
            self._promote(block=True)
        _, batch, acand, out, route = self._inflight.popleft()
        # decode + emit run on the decode worker, overlapping device
        # execution AND this thread's submit/promote work
        self._decoding.append(
            self._decode_ex.submit(self._decode_job, batch, acand, out, route)
        )
        # promote any counts that landed meanwhile, then bound the decode
        # backlog (it holds references to device buffers)
        self._promote(block=False)
        while len(self._decoding) > self._decode_depth:
            self._decoding.popleft().result()

    def _drain(self) -> None:
        while self._pending or self._inflight:
            self._harvest_one()
        while self._decoding:
            self._decoding.popleft().result()

    def consume(self, stream, on_spill=None) -> Tuple[int, int]:
        """Emit-mode twin of :meth:`Dispatcher.consume`.

        Pulls from the (possibly parallel-producer) stream, submitting
        packed batches and routing oversize spill tiles to ``on_spill``
        (which must route their rows through :meth:`emit_rows` so stream
        order is preserved).  The stream may interleave several requests
        by wrapping items in :class:`Routed` (see :meth:`submit`); routed
        spills call ``on_spill(tile, route)``.  Stops early once the
        dispatcher-global sink reports ``full`` (per-request early stop
        is the routes' business).  Returns (tiles consumed, max tile
        width).
        """
        stop = None
        if self.sink is not None:
            stop = lambda: self.sink.full  # noqa: E731
        return _consume_stream(self, stream, on_spill, stop=stop)

    def drain(self) -> None:
        """Block until every submitted batch is decoded and delivered.

        The long-lived-service twin of :meth:`finish`: flushes pending
        count passes, in-flight list kernels, and the decode-worker
        backlog (so all routes/sink writes for prior submits have run),
        but keeps the decode worker alive for further ``submit`` calls.
        """
        self._drain()

    def finish(self) -> int:
        """Drain all in-flight batches; returns rows accepted by the sink.

        Shuts down the decode worker -- use :meth:`drain` instead to keep
        the dispatcher serving.  Returns 0 when running sink-less (all
        batches routed).
        """
        from ..kernels import ops as kops

        self._drain()
        self._decode_ex.shutdown(wait=True)
        self.stats.kernel_compile_s += kops.consume_compile_s()
        kops.drain_tune_events(self.stats)
        return 0 if self.sink is None else self.sink.accepted

    def close(self) -> None:
        """Best-effort teardown for error paths: cancel queued decode
        jobs and stop the worker WITHOUT draining devices, so the sink
        stops receiving rows once the caller is handling a failure.

        Queued (never-started) jobs are cancelled, but the one decode job
        the single worker may be running is drained to its row boundary:
        ``shutdown(cancel_futures=True)`` alone would return while that
        job is mid-``sink.emit``, letting the caller tear the sink down
        under a concurrent write (torn row).  Draining the future deque
        is the barrier -- cancelled futures resolve instantly, the
        running one completes its emit first.  Idempotent; a no-op after
        a clean :meth:`finish`."""
        self._decode_ex.shutdown(wait=False, cancel_futures=True)
        while self._decoding:
            fut = self._decoding.popleft()
            try:
                fut.result()
            except concurrent.futures.CancelledError:
                continue
            except Exception:
                # error-path teardown: the primary failure is already
                # being handled by the caller
                pass


def dispatch_scheduled(
    batches: Sequence[pipeline.TileBatch],
    l: int,
    devices: Union[None, int, str, Sequence] = None,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    et: bool = True,
    method: str = "auto",
    interpret: Optional[bool] = None,
    backend: Optional[str] = None,
    async_staging: bool = True,
    max_inflight: int = 2,
    stats: Optional[Stats] = None,
    stage_times: Optional[dict] = None,
) -> Tuple[int, dict]:
    """Offline-LPT dispatch of a materialized batch list.

    ``schedule_batches`` LPT-assigns whole batches to ``n_devices`` bins;
    each bin becomes one real device, and bins are drained round-robin so
    every device receives work from the first wave of submissions.
    Returns (total, info) where info carries the scheduler stats plus the
    realized per-batch ``placements``.
    """
    disp = Dispatcher(
        l,
        devices,
        mesh=mesh,
        et=et,
        method=method,
        interpret=interpret,
        backend=backend,
        async_staging=async_staging,
        max_inflight=max_inflight,
        stats=stats,
        stage_times=stage_times,
    )
    if mesh is not None:
        for b in batches:
            disp.submit(b)
        info = {"n_devices": disp.n_devices, "mesh": True}
    else:
        device_bins, sched = schedule_batches(batches, l, disp.n_devices)
        for wave in itertools.zip_longest(*device_bins):
            for d, bi in enumerate(wave):
                if bi is not None:
                    disp.submit(batches[bi], device=d)
        info = dict(sched)
        info["n_devices"] = disp.n_devices
        info["device_bins"] = device_bins
    total = disp.finish()
    info["placements"] = disp.placements
    info["tiles"] = disp.tiles
    return total, info
