"""Training / serving runtime: fault-tolerant loops + clique scheduler."""
from .train_loop import TrainLoop, TrainLoopConfig
from .clique_scheduler import (balanced_bins, schedule_batches,
                               schedule_tiles, tile_costs)
