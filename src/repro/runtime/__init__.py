"""Training / serving runtime: fault-tolerant loops, clique scheduler,
multi-device tile dispatch."""
from .train_loop import TrainLoop, TrainLoopConfig
from .clique_scheduler import (balanced_bins, schedule_batches,
                               schedule_tiles, tile_costs)
from .dispatch import Dispatcher, dispatch_scheduled, resolve_devices
