"""AdamW with decoupled weight decay and global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: Optional[Callable] = None  # step -> lr multiplier


def adamw_init(params):
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 wd_mask=None):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    lr = cfg.lr * (cfg.schedule(count) if cfg.schedule else 1.0)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v
                      + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["nu"], grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    if wd_mask is None:
        wd_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, m, v, wd):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * jnp.where(wd, p, 0.0
                                                       ).astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu, wd_mask)
    return new_params, {"mu": mu, "nu": nu, "count": count}, {
        "grad_norm": gnorm, "lr": lr}
