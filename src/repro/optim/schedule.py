"""Learning-rate schedules (return multiplier for AdamWConfig.schedule)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def linear_schedule(warmup: int, total: int):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        dec = jnp.clip(1.0 - (step - warmup) / jnp.maximum(total - warmup, 1),
                       0.0, 1.0)
        return jnp.where(step < warmup, warm, dec)
    return fn
