"""Optimizers, schedules, gradient clipping and gradient compression.

Self-contained (no optax): AdamW over arbitrary pytrees with optimizer
state sharded identically to the parameters (first-moment/second-moment
trees inherit the param PartitionSpecs in the launcher).
"""
from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm, clip_by_global_norm
from .schedule import cosine_schedule, linear_schedule
from .compress import (int8_compress, int8_decompress, compressed_allreduce,
                       compressed_psum_tree)
