"""Int8 gradient compression with error feedback for DP all-reduce.

Scheme (1-bit-Adam / PowerSGD deployment style, adapted to int8): a ring
all-reduce is reduce_scatter + all_gather.  The reduce_scatter stays f32
(exact accumulation); the all_gather half of the traffic is sent as int8 +
per-shard f32 scale.  Wire bytes drop from 2*N*4 to N*4 + N*1 = 0.625x, and
the saving is visible in the lowered HLO (the all-gather operand is s8) --
see EXPERIMENTS.md section Perf.  The quantization residual is carried in an
error-feedback buffer so the long-run update is unbiased.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array, err: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce(x: jax.Array, err: jax.Array,
                         axis_name: Optional[str]):
    """Mean-all-reduce of ``x`` over ``axis_name`` with int8 all-gather.

    Must run inside shard_map (needs a bound axis name).  With
    ``axis_name=None`` degrades to a quantize/dequantize round trip.
    Returns (reduced, new_err) with ``reduced`` replicated over the axis.
    """
    if axis_name is None:
        q, scale, new_err = int8_compress(x, err)
        return int8_decompress(q, scale), new_err
    n = jax.lax.axis_size(axis_name)
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # exact f32 reduce_scatter: each shard owns 1/n of the summed gradient
    mine = jax.lax.psum_scatter(flat.reshape(n, -1), axis_name,
                                scatter_dimension=0, tiled=False) / n
    # quantize own shard (with persistent error feedback on the shard)
    err_flat = err.reshape(-1)
    my_err = jax.lax.dynamic_slice_in_dim(
        jnp.pad(err_flat, (0, pad)),
        jax.lax.axis_index(axis_name) * mine.shape[0], mine.shape[0], 0)
    q, scale, new_my_err = int8_compress(mine, my_err)
    # int8 all-gather (the compressed half of the ring)
    q_all = jax.lax.all_gather(q, axis_name, axis=0, tiled=False)
    s_all = jax.lax.all_gather(scale, axis_name, axis=0, tiled=False)
    full = (q_all.astype(jnp.float32) * s_all[:, None]).reshape(-1)
    # scatter the updated error shard back into the (replicated) buffer
    new_err_flat = jnp.zeros_like(jnp.pad(err_flat, (0, pad)))
    new_err_flat = jax.lax.dynamic_update_slice_in_dim(
        new_err_flat, new_my_err,
        jax.lax.axis_index(axis_name) * mine.shape[0], 0)
    new_err_flat = jax.lax.psum(new_err_flat, axis_name)
    if pad:
        full = full[:-pad]
        new_err_flat = new_err_flat[:-pad]
    return full.reshape(shape), new_err_flat.reshape(shape)


def compressed_psum_tree(grads, err_tree, axis_name: Optional[str]):
    """Apply compressed_allreduce leaf-wise over a gradient pytree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs = [compressed_allreduce(g, e, axis_name)
            for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
