"""Deterministic, resumable, shard-aware synthetic data pipelines."""
from .lm import LMDataPipeline
from .graphs import (rmat_graph, powerlaw_graph, erdos_renyi, planted_cliques,
                     GraphBatcher)
from .recsys import RecsysPipeline
from .sampler import NeighborSampler
