"""Synthetic LM token pipeline: deterministic per (seed, shard, step).

Tokens for step s are a pure function of (seed, shard_id, s) -- restart at
any step reproduces the exact stream, which is what makes checkpoint/resume
bitwise reproducible (tested).  State is one integer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class LMDataPipeline:
    vocab: int
    batch: int            # per-shard batch
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    step: int = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard_id, self.step]))
        # markov-ish stream so the loss is learnable, not pure noise
        base = rng.integers(0, self.vocab, size=(self.batch, self.seq_len),
                            dtype=np.int32)
        drift = np.cumsum(rng.integers(0, 3, base.shape, dtype=np.int32) - 1,
                          axis=1)
        tokens = np.abs(base // 7 + drift) % self.vocab
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -100
        self.step += 1
        return {"tokens": tokens.astype(np.int32), "labels": labels}

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed,
                "shard_id": self.shard_id, "num_shards": self.num_shards}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])
