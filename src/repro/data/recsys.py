"""Synthetic CTR stream with a planted logistic ground truth (so DCN-v2
training has signal); deterministic + resumable like the other pipelines."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class RecsysPipeline:
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1000
    batch: int = 256
    bag: int = 1
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 999)
        self._w_dense = rng.normal(size=(self.n_dense,)).astype(np.float32)
        self._w_field = rng.normal(size=(self.n_sparse,)).astype(np.float32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step]))
        dense = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
        sparse = rng.integers(0, self.vocab,
                              size=(self.batch, self.n_sparse, self.bag),
                              dtype=np.int32)
        logit = dense @ self._w_dense + (
            (sparse[..., 0] % 7 - 3) * self._w_field).sum(-1) * 0.1
        labels = (rng.random(self.batch) < 1 /
                  (1 + np.exp(-logit))).astype(np.float32)
        self.step += 1
        return {"dense": dense, "sparse": sparse, "labels": labels}

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])
