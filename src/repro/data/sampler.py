"""GraphSAGE-style layered neighbor sampler (minibatch_lg shape).

Produces fixed-shape sampled blocks: seed nodes (batch,), then per hop a
padded (n_prev * fanout) frontier with masks -- ready for segment_sum
message passing on device.  Sampling runs on host CSR (the data-pipeline
tier of the system); deterministic per (seed, step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from ..core.graph import Graph


@dataclasses.dataclass
class NeighborSampler:
    graph: Graph
    batch_nodes: int
    fanouts: Sequence[int]          # e.g. (15, 10)
    seed: int = 0
    step: int = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step]))
        g = self.graph
        seeds = rng.integers(0, g.n, size=self.batch_nodes).astype(np.int64)
        layers = [seeds]
        blocks = []
        frontier = seeds
        for fanout in self.fanouts:
            nbrs = np.zeros((len(frontier), fanout), dtype=np.int64)
            mask = np.zeros((len(frontier), fanout), dtype=np.float32)
            for i, v in enumerate(frontier):
                adj = g.indices[g.indptr[v]:g.indptr[v + 1]]
                if len(adj) == 0:
                    continue
                take = rng.choice(adj, size=fanout,
                                  replace=len(adj) < fanout)
                nbrs[i] = take
                mask[i] = 1.0
            blocks.append({"nbrs": nbrs, "mask": mask})
            frontier = nbrs.reshape(-1)
            layers.append(frontier)
        self.step += 1
        return {"seeds": seeds, "blocks": blocks}

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])


def sampled_block_shapes(batch_nodes: int, fanouts: Sequence[int],
                         d_feat: int):
    """ShapeDtypeStruct-compatible shape dict for the dry-run input specs."""
    shapes = {"seed_feats": ((batch_nodes, d_feat), np.float32)}
    prev = batch_nodes
    for h, f in enumerate(fanouts):
        shapes[f"hop{h}_feats"] = ((prev * f, d_feat), np.float32)
        shapes[f"hop{h}_mask"] = ((prev * f,), np.float32)
        prev *= f
    return shapes
