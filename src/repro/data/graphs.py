"""Graph generators (offline substitutes for the paper's 19 SNAP graphs)
and a padded-batch builder for GNN training.

Generators are calibrated to the paper's regimes: power-law graphs have
tau/delta well below 1 (Table 1's social/web graphs), planted-clique graphs
approach tau ~ delta (the dense DB/CI/WE family).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..core.graph import Graph, from_edges


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    ii, jj = np.triu_indices(n, k=1)
    keep = rng.random(len(ii)) < p
    return from_edges(n, np.stack([ii[keep], jj[keep]], 1))


def powerlaw_graph(n: int, m_per_node: int, seed: int = 0) -> Graph:
    """Barabasi-Albert style preferential attachment (vectorized-ish)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    repeated: list = []
    edges = []
    for v in range(m_per_node, n):
        ts = set()
        pool = repeated if repeated else targets
        while len(ts) < m_per_node:
            ts.add(int(pool[rng.integers(0, len(pool))]))
        for t in ts:
            edges.append((v, t))
            repeated.extend([v, t])
    return from_edges(n, np.asarray(edges, dtype=np.int64))


def rmat_graph(scale: int, edge_factor: int = 8, seed: int = 0,
               a=0.57, b=0.19, c=0.19) -> Graph:
    """RMAT / Graph500-style generator."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    keep = src != dst
    return from_edges(n, np.stack([src[keep], dst[keep]], 1))


def planted_cliques(n: int, n_cliques: int, clique_size: int,
                    p_noise: float = 0.01, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    edges = []
    for _ in range(n_cliques):
        verts = rng.choice(n, size=clique_size, replace=False)
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((verts[i], verts[j]))
    ii, jj = np.triu_indices(n, k=1)
    keep = rng.random(len(ii)) < p_noise
    edges.extend(zip(ii[keep].tolist(), jj[keep].tolist()))
    return from_edges(n, np.asarray(edges, dtype=np.int64))


@dataclasses.dataclass
class GraphBatcher:
    """Deterministic resumable batches of small graphs (molecule regime)."""
    n_nodes: int = 30
    n_edges: int = 64
    batch: int = 128
    d_feat: int = 16
    seed: int = 0
    step: int = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step]))
        B, N, E = self.batch, self.n_nodes, self.n_edges
        feats = rng.normal(size=(B * N, self.d_feat)).astype(np.float32)
        pos = rng.normal(size=(B * N, 3)).astype(np.float32)
        src = rng.integers(0, N, size=(B, E))
        dst = (src + 1 + rng.integers(0, N - 1, size=(B, E))) % N
        offset = (np.arange(B) * N)[:, None]
        edges = np.stack([(src + offset).reshape(-1),
                          (dst + offset).reshape(-1)], 0).astype(np.int32)
        graph_ids = np.repeat(np.arange(B, dtype=np.int32), N)
        # synthetic label: a smooth function of mean pairwise distance
        y = np.tanh(pos.reshape(B, N, 3).std(axis=(1, 2))).astype(np.float32)
        self.step += 1
        return {"nodes": feats, "pos": pos, "edges": edges,
                "edge_mask": np.ones(edges.shape[1], np.float32),
                "graph_ids": graph_ids, "labels": y}

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])
