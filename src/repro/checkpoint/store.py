"""Sharded numpy checkpoints: atomic, resumable, mesh-elastic.

Layout: <dir>/step_<N>/{arrays.npz, meta.json, COMMITTED}

* **Atomic**: written to ``step_<N>.tmp`` then ``os.replace``d; a crash
  mid-write never corrupts the latest checkpoint; restore picks the newest
  *committed* step.
* **Elastic**: arrays are stored as full logical values (gathered); restore
  re-device_puts under whatever shardings the *restarted* mesh provides, so
  a job can come back on a different topology (tested 8 -> 4 devices).
  At real scale this becomes per-shard files + a reshard service; the
  commit protocol and logical-value contract stay identical.
* Pipeline state and arbitrary JSON metadata ride along.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import numpy as np
import jax


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/#{i}", v)
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(f"{prefix}/#{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix]
    return rec("", template)


def save_checkpoint(directory: str, step: int, tree,
                    pipeline_state: Optional[Dict] = None,
                    metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "pipeline": pipeline_state or {},
            "metadata": metadata or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, template=None,
                       step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``template``.

    With ``template=None`` the flat array dict is returned as the tree
    (keys are the flattened ``a/b/#i`` paths) -- the schema-free mode used
    by consumers whose structure is data-dependent, e.g. the
    :mod:`repro.core.pipeline` plan store (a plan may or may not carry a
    truss decomposition, coloring, or either membership table).

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding
    -- this is the elastic-rescale path: arrays are placed under the *new*
    mesh regardless of the topology that wrote them.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    path = os.path.join(directory, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: data[k] for k in data.files}
    tree = flat if template is None else _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings,
            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return {"step": step, "tree": tree, "pipeline": meta["pipeline"],
            "metadata": meta["metadata"]}


def gc_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1)) for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)
