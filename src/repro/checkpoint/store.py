"""Sharded numpy checkpoints: atomic, resumable, mesh-elastic, verified.

Layout: <dir>/step_<N>/{arrays.npz, meta.json, COMMITTED}

* **Atomic**: written to ``step_<N>.tmp`` then ``os.replace``d; a crash
  mid-write never corrupts the latest checkpoint; restore picks the newest
  *committed* step.
* **Verified**: ``meta.json`` carries a length + sha256 trailer over the
  raw ``arrays.npz`` bytes; restore checks it before deserializing, so
  bit-rot or truncation surfaces as a typed
  :class:`CorruptCheckpointError` instead of a numpy traceback.  Consumers
  with a rebuild path (plan cache, tune records) pair this with
  :func:`quarantine` to move the bad step aside and fall back to absent.
* **Elastic**: arrays are stored as full logical values (gathered); restore
  re-device_puts under whatever shardings the *restarted* mesh provides, so
  a job can come back on a different topology (tested 8 -> 4 devices).
  At real scale this becomes per-shard files + a reshard service; the
  commit protocol and logical-value contract stay identical.
* Pipeline state and arbitrary JSON metadata ride along.
"""
from __future__ import annotations

import hashlib
import io
import itertools
import json
import logging
import os
import re
import shutil
from typing import Any, Dict, Optional

import numpy as np
import jax

from ..resilience import inject

log = logging.getLogger("repro.checkpoint")

# unique suffixes for quarantined step dirs within one process
_QUAR_IDS = itertools.count()


class CorruptCheckpointError(RuntimeError):
    """A committed checkpoint failed integrity checks or did not parse."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/#{i}", v)
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(f"{prefix}/#{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix]
    return rec("", template)


def save_checkpoint(directory: str, step: int, tree,
                    pipeline_state: Optional[Dict] = None,
                    metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    arr_path = os.path.join(tmp, "arrays.npz")
    np.savez(arr_path, **arrays)
    with open(arr_path, "rb") as f:
        blob = f.read()
    meta = {"step": step, "pipeline": pipeline_state or {},
            "metadata": metadata or {},
            "integrity": {"nbytes": len(blob),
                          "sha256": hashlib.sha256(blob).hexdigest()}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, template=None,
                       step: Optional[int] = None, shardings=None,
                       _corrupt_site: Optional[str] = None):
    """Restore into the structure of ``template``.

    With ``template=None`` the flat array dict is returned as the tree
    (keys are the flattened ``a/b/#i`` paths) -- the schema-free mode used
    by consumers whose structure is data-dependent, e.g. the
    :mod:`repro.core.pipeline` plan store (a plan may or may not carry a
    truss decomposition, coloring, or either membership table).

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding
    -- this is the elastic-rescale path: arrays are placed under the *new*
    mesh regardless of the topology that wrote them.

    Integrity: when ``meta.json`` carries the length+sha256 trailer (every
    store written since it was introduced), the raw ``arrays.npz`` bytes
    are verified *before* deserialization.  Any mismatch, unreadable file,
    or parse failure raises :class:`CorruptCheckpointError` (never a raw
    numpy/json traceback).  ``_corrupt_site`` threads the named
    fault-injection site whose ``corrupt`` rule mutates the blob between
    read and verify (chaos-testing the detection path).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    path = os.path.join(directory, f"step_{step:010d}")
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            blob = f.read()
        if _corrupt_site is not None:
            blob = inject.corrupt_bytes(_corrupt_site, blob)
        integ = meta.get("integrity")
        if integ is not None and (
            integ.get("nbytes") != len(blob)
            or integ.get("sha256") != hashlib.sha256(blob).hexdigest()
        ):
            raise CorruptCheckpointError(
                f"{path}: arrays.npz failed its length+digest check")
        data = np.load(io.BytesIO(blob))
        flat = {k: data[k] for k in data.files}
        pipeline_state = meta["pipeline"]
        metadata = meta["metadata"]
    except CorruptCheckpointError:
        raise
    except Exception as exc:
        raise CorruptCheckpointError(
            f"{path}: unreadable checkpoint ({exc!r})") from exc
    tree = flat if template is None else _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings,
            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
    return {"step": step, "tree": tree, "pipeline": pipeline_state,
            "metadata": metadata}


def read_metadata(directory: str, step: Optional[int] = None
                  ) -> Optional[Dict]:
    """The ``metadata`` dict of a committed step, without loading arrays.

    Cheap lineage/inventory probe (e.g. a plan store's version lineage):
    reads only ``meta.json``.  Returns None when the step is absent or
    the metadata is unreadable -- integrity of the array blob is *not*
    checked here (that happens on the full :func:`restore_checkpoint`).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    path = os.path.join(directory, f"step_{step:010d}", "meta.json")
    try:
        with open(path) as f:
            return json.load(f).get("metadata", {})
    except (OSError, ValueError):
        return None


def quarantine(directory: str, step: Optional[int] = None,
               reason: str = "") -> Optional[str]:
    """Move a (corrupt) checkpoint step aside into ``<dir>/quarantine/``.

    The graceful-degradation half of :class:`CorruptCheckpointError`:
    instead of deleting evidence or letting every restore hit the same
    bad file, the step directory is renamed under ``quarantine/`` (same
    filesystem, atomic) so the next save rebuilds cleanly while the bad
    bytes stay inspectable.  Best-effort: returns the quarantine path, or
    None when there was nothing to move.  Never raises.
    """
    try:
        if step is None:
            step = latest_step(directory)
        if step is None:
            return None
        src = os.path.join(directory, f"step_{step:010d}")
        if not os.path.isdir(src):
            return None
        qdir = os.path.join(directory, "quarantine")
        os.makedirs(qdir, exist_ok=True)
        dst = os.path.join(
            qdir, f"step_{step:010d}.{os.getpid()}.{next(_QUAR_IDS)}")
        os.replace(src, dst)
    except OSError:
        return None
    log.warning("quarantined corrupt checkpoint %s -> %s (%s)",
                src, dst, reason or "integrity check failed")
    return dst


def restore_checkpoint_safe(directory: str, template=None,
                            step: Optional[int] = None, shardings=None,
                            _corrupt_site: Optional[str] = None):
    """:func:`restore_checkpoint` with the fall-back-to-absent contract.

    A corrupt or unreadable step is quarantined (moved aside with a
    warning log) and reads as absent (``None``), so callers with a
    rebuild path -- the plan cache, tune records -- regenerate instead of
    propagating deserialization tracebacks.
    """
    try:
        return restore_checkpoint(directory, template, step, shardings,
                                  _corrupt_site=_corrupt_site)
    except CorruptCheckpointError as exc:
        quarantine(directory, step, reason=str(exc))
        return None


def gc_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1)) for name in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)
