"""Fault-tolerant numpy checkpointing with elastic re-shard on restore."""
from .store import (save_checkpoint, restore_checkpoint, latest_step,
                    gc_checkpoints)
