"""Logical axes -> mesh axes.

Parameters and inputs are annotated with *logical* axis names; a rules
table maps them to physical mesh axes ("pod", "data", "model").  This is
the single place where the parallelism layout of every architecture is
decided; changing a rule re-lays-out the whole system (tested via the
multi-pod dry-run for all 40 cells).

LM layout (Megatron-style TP + hierarchical DP):
  heads / ff / experts / vocab -> "model";  batch -> ("pod", "data")
GNN full-batch layout: nodes/edges -> ("pod", "data"); features "model"
  only for the very wide layers (kept replicated otherwise -- segment_sum
  over sharded edges produces partial node sums that psum over data).
Recsys: embedding rows -> "model" (the tables are the model);
  batch -> ("pod", "data").
Clique engine: tiles (the EP axis of the paper) -> all axes flattened.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    table: Dict[str, Optional[Tuple[str, ...]]]

    def axis(self, name: Optional[str]):
        if name is None:
            return None
        got = self.table.get(name, None)
        return got


LM_RULES = LogicalRules({
    "batch": ("pod", "data"),
    "seq": None,
    # FSDP: the d_model axis of every weight is sharded over the data axis
    # for *storage*; XLA all-gathers each layer's weights at use (ZeRO-3).
    # Without this, a 132B-param arch needs >100 GB/device (measured in the
    # first dry-run iteration -- see EXPERIMENTS.md section Perf).
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "layers": None,
    "cache_len": None,
})

GNN_RULES = LogicalRules({
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "feat": None,
    "hidden": None,
    "graphs": ("pod", "data"),
})

RECSYS_RULES = LogicalRules({
    "batch": ("pod", "data"),
    "rows": ("model",),
    "dim": None,
    "fields": None,
    "candidates": ("model",),
})

CLIQUE_RULES = LogicalRules({
    "tiles": ("pod", "data", "model"),
    "tile_v": None,
    "tile_w": None,
})


def spec_for(rules: LogicalRules, logical_axes: Tuple[Optional[str], ...]
             ) -> P:
    parts = []
    for ax in logical_axes:
        m = rules.axis(ax)
        if m is None:
            parts.append(None)
        elif len(m) == 1:
            parts.append(m[0])
        else:
            parts.append(tuple(m))
    return P(*parts)


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


# ---------------------------------------------------------------------------
# per-model logical annotations
# ---------------------------------------------------------------------------

def transformer_param_specs(cfg, rules: LogicalRules = LM_RULES,
                            model_size: int = 1):
    """PartitionSpec tree matching models.transformer.init_params.

    ``model_size``: TP degree.  KV heads are *replicated* when n_kv_heads
    is not divisible by it (GQA with kv < TP -- standard MaxText/Megatron
    fallback); same guard for q heads.
    """
    def s(*ax):
        return spec_for(rules, ax)
    kv_ax = "kv_heads" if cfg.n_kv_heads % max(model_size, 1) == 0 else None
    q_ax = "heads" if cfg.n_heads % max(model_size, 1) == 0 else None
    group = {
        "ln1": s("layers", "embed"),
        "ln2": s("layers", "embed"),
        "wq": s("layers", "embed", q_ax, "head_dim"),
        "wk": s("layers", "embed", kv_ax, "head_dim"),
        "wv": s("layers", "embed", kv_ax, "head_dim"),
        "wo": s("layers", q_ax, "head_dim", "embed"),
    }
    if cfg.moe:
        group.update({
            "router": s("layers", "embed", None),
            "we1": s("layers", "experts", "embed", None),
            "we3": s("layers", "experts", "embed", None),
            "we2": s("layers", "experts", None, "embed"),
        })
        if cfg.moe.n_shared:
            group.update({
                "ws1": s("layers", "embed", "ff"),
                "ws3": s("layers", "embed", "ff"),
                "ws2": s("layers", "ff", "embed"),
            })
    else:
        group.update({
            "w1": s("layers", "embed", "ff"),
            "w2": s("layers", "ff", "embed"),
        })
        if cfg.gated:
            group["w3"] = s("layers", "embed", "ff")
    return {
        # embed/head: vocab-sharded only.  FSDP-sharding their d_model axis
        # triggers XLA "involuntary full rematerialization" on the token
        # gather (measured on granite-3-8b); the tables are only
        # O(vocab*d/model) bytes so data-axis sharding buys nothing.
        "embed": s("vocab", "embed_noshard"),
        "final_ln": s("embed"),
        "head": s("embed_noshard", "vocab"),
        "groups": {kind: dict(group) for kind, _ in cfg.layer_groups},
    }


def transformer_layer_specs(cfg, model_size: int = 1):
    """Per-layer (sliced) weight specs: model-axis sharding only.

    Applied as a with_sharding_constraint inside the scan body so the
    FSDP (data-axis) all-gather happens per layer *inside* the loop --
    without it XLA hoists one gather of the whole stacked stack out of
    the scan (measured +92 GB temp on dbrx-132b; EXPERIMENTS.md Perf).
    """
    kv_ax = "model" if cfg.n_kv_heads % max(model_size, 1) == 0 else None
    q_ax = "model" if cfg.n_heads % max(model_size, 1) == 0 else None
    specs = {
        "ln1": P(None),
        "ln2": P(None),
        "wq": P(None, q_ax, None),
        "wk": P(None, kv_ax, None),
        "wv": P(None, kv_ax, None),
        "wo": P(q_ax, None, None),
    }
    if cfg.moe:
        specs.update({
            "router": P(None, None),
            "we1": P("model", None, None),
            "we3": P("model", None, None),
            "we2": P("model", None, None),
        })
        if cfg.moe.n_shared:
            specs.update({"ws1": P(None, "model"), "ws3": P(None, "model"),
                          "ws2": P("model", None)})
    else:
        specs.update({"w1": P(None, "model"), "w2": P("model", None)})
        if cfg.gated:
            specs["w3"] = P(None, "model")
    return specs


def transformer_cache_specs(cfg, rules: LogicalRules = LM_RULES,
                            model_size: int = 1):
    def s(*ax):
        return spec_for(rules, ax)
    if cfg.n_kv_heads % max(model_size, 1) == 0:
        kv = s("layers", "batch", "cache_len", "kv_heads", "head_dim")
    else:
        # kv heads not shardable over TP: shard the cache length instead
        kv = P(None, spec_for(rules, ("batch",))[0], "model", None, None)
    return {kind: {"k": kv, "v": kv} for kind, _ in cfg.layer_groups}


def batch_specs(rules: LogicalRules, names: Dict[str, Tuple[Optional[str], ...]]):
    return {k: spec_for(rules, ax) for k, ax in names.items()}
