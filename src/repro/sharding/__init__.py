"""Logical-axis sharding rules -> PartitionSpecs / NamedShardings."""
from .rules import (LogicalRules, LM_RULES, GNN_RULES, RECSYS_RULES,
                    CLIQUE_RULES, spec_for, tree_shardings,
                    transformer_param_specs, batch_specs)
