"""Decoder-only transformer family covering the five assigned LM archs.

One flexible implementation:
  * GQA attention + RoPE, causal, f32 softmax, chunked (flash-style) scores
    so 32k prefill never materializes (S, S);
  * optional sliding-window "local" layers (gemma3's 5:1 local:global) --
    local layers only read a window-sized KV slice (sub-quadratic state);
  * dense FFN (gated silu/gelu or squared-ReLU) or MoE (shared + routed
    fine-grained experts, top-k, capacity-based dispatch under shard_map
    with expert parallelism on the "model" mesh axis);
  * stacked-layer lax.scan per layer *group* with remat.

Hardware-adaptation note (DESIGN.md section 8): layers are grouped by kind
(local/global) and scanned group-wise rather than interleaved 5:1; per-step
FLOPs, memory and collectives are identical, only the (synthetic) numerics
of layer order differ.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from .common import act_fn, apply_rope, normal_init, rms_norm


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated: bool = True
    moe: Optional[MoEConfig] = None
    local_window: Optional[int] = None
    local_per_global: int = 0        # 5 -> gemma-style 5:1; 0 -> all global
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_block: int = 512               # query block for chunked attention
    analysis_unroll: bool = False    # unroll all scans (cost-analysis mode:
    #   XLA cost_analysis counts while-loop bodies once; the dry-run lowers
    #   unrolled probe models to extrapolate true per-step FLOPs/bytes)
    groups_override: Any = None      # ((kind, count), ...) probe override

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables pad the vocab to a multiple of 512 so the
        vocab axis shards over any mesh (standard table padding; the loss
        never selects padded ids)."""
        return -(-self.vocab // 512) * 512

    @property
    def layer_groups(self) -> List[Tuple[str, int]]:
        if self.groups_override is not None:
            return [tuple(g) for g in self.groups_override]
        if self.local_per_global <= 0 or self.local_window is None:
            return [("global", self.n_layers)]
        n_global = self.n_layers // (self.local_per_global + 1)
        return [("local", self.n_layers - n_global), ("global", n_global)]

    def num_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.moe:
            e = self.moe
            ffn = e.n_experts * 3 * d * e.d_expert + d * e.n_experts \
                + e.n_shared * 3 * d * e.d_expert
        else:
            ffn = (3 if self.gated else 2) * d * f
        return self.n_layers * (attn + ffn + 2 * d) + 2 * v * d + d

    def active_params(self) -> int:
        """Per-token active parameters (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.num_params()
        d = self.d_model
        e = self.moe
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        ffn = (e.top_k + e.n_shared) * 3 * d * e.d_expert + d * e.n_experts
        return self.n_layers * (attn + ffn + 2 * d) + 2 * self.vocab * d + d


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """How the model maps onto the mesh (None = single device)."""
    mesh: Optional[Any] = None
    data_axes: Tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    layer_specs: Optional[Dict] = None  # per-layer weight gather constraint


def _gather_layer(lp: Dict, ctx: "ShardCtx") -> Dict:
    """FSDP per-layer gather: constrain the sliced layer weights to their
    compute (model-axis-only) sharding inside the scan body."""
    if ctx.mesh is None or ctx.layer_specs is None:
        return lp
    from jax.sharding import NamedSharding
    out = dict(lp)
    for k, spec in ctx.layer_specs.items():
        if k in out:
            out[k] = jax.lax.with_sharding_constraint(
                out[k], NamedSharding(ctx.mesh, spec))
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer_stack(key, cfg: TransformerConfig, count: int):
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 12)
    p = {
        "ln1": jnp.zeros((count, d), jnp.float32),
        "ln2": jnp.zeros((count, d), jnp.float32),
        "wq": normal_init(ks[0], (count, d, cfg.n_heads, dh), d ** -0.5),
        "wk": normal_init(ks[1], (count, d, cfg.n_kv_heads, dh), d ** -0.5),
        "wv": normal_init(ks[2], (count, d, cfg.n_kv_heads, dh), d ** -0.5),
        "wo": normal_init(ks[3], (count, cfg.n_heads, dh, d),
                          (cfg.n_heads * dh) ** -0.5),
    }
    if cfg.moe:
        e = cfg.moe
        fe = e.d_expert
        p["router"] = normal_init(ks[4], (count, d, e.n_experts), d ** -0.5)
        p["we1"] = normal_init(ks[5], (count, e.n_experts, d, fe), d ** -0.5)
        p["we3"] = normal_init(ks[6], (count, e.n_experts, d, fe), d ** -0.5)
        p["we2"] = normal_init(ks[7], (count, e.n_experts, fe, d), fe ** -0.5)
        if e.n_shared:
            fs = e.n_shared * fe
            p["ws1"] = normal_init(ks[8], (count, d, fs), d ** -0.5)
            p["ws3"] = normal_init(ks[9], (count, d, fs), d ** -0.5)
            p["ws2"] = normal_init(ks[10], (count, fs, d), fs ** -0.5)
    else:
        f = cfg.d_ff
        p["w1"] = normal_init(ks[4], (count, d, f), d ** -0.5)
        p["w2"] = normal_init(ks[5], (count, f, d), f ** -0.5)
        if cfg.gated:
            p["w3"] = normal_init(ks[6], (count, d, f), d ** -0.5)
    return p


def init_params(key, cfg: TransformerConfig) -> Dict:
    keys = jax.random.split(key, 3 + len(cfg.layer_groups))
    params = {
        "embed": normal_init(keys[0], (cfg.padded_vocab, cfg.d_model), 0.02),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "head": normal_init(keys[1], (cfg.d_model, cfg.padded_vocab),
                            cfg.d_model ** -0.5),
        "groups": {},
    }
    for i, (kind, count) in enumerate(cfg.layer_groups):
        params["groups"][kind] = _init_layer_stack(keys[3 + i], cfg, count)
    return params


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, bias):
    """q: (B,Qb,Hk,G,D); k/v: (B,Skv,Hk,D); bias: (Qb,Skv) additive mask."""
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    scores = scores * (q.shape[-1] ** -0.5) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_block: int, unroll_blocks: bool = False):
    """Flash-style blocked attention; never materializes (S, S).

    q: (B,S,Hq,D), k/v: (B,S,Hk,D). Local layers slice KV to the window
    around each query block (sub-quadratic compute and memory).
    """
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    qb = min(q_block, S)
    nblk = (S + qb - 1) // qb
    pad = nblk * qb - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(B, nblk, qb, Hk, G, D)

    kv_span = S if window is None else min(S, window + qb)

    def one_block(i, qi):
        # qi: (B,qb,Hk,G,D)
        q0 = i * qb
        if window is None:
            ks, vs = k, v
            kpos = jnp.arange(S)
        else:
            start = jnp.clip(q0 + qb - kv_span, 0, S - kv_span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kpos = start + jnp.arange(kv_span)
        qpos = q0 + jnp.arange(qb)
        mask = jnp.ones((qb, kpos.shape[0]), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
        return _attend_block(qi, ks, vs, bias)

    unroll = nblk if unroll_blocks else 1
    out = jax.lax.scan(
        lambda c, args: (c, one_block(*args)), None,
        (jnp.arange(nblk), qr.swapaxes(0, 1)), unroll=unroll)[1]
    out = out.swapaxes(0, 1).reshape(B, nblk * qb, Hq, D)
    return out[:, :S]


def decode_attention(q, k_cache, v_cache, lengths, *, window: Optional[int]):
    """One-token attention against a cache.

    q: (B,1,Hq,D); caches: (B,Sc,Hk,D); lengths: (B,) valid entries.
    For local layers the cache is a rolling buffer of size window and all
    entries are valid once full.
    """
    B, Sc, Hk, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hk
    qr = q.reshape(B, 1, Hk, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k_cache).astype(jnp.float32)
    scores = scores * (D ** -0.5)
    pos = jnp.arange(Sc)[None, :]
    valid = pos < lengths[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, 1, Hq, D)


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------

def dense_ffn(x, p, cfg: TransformerConfig):
    a = act_fn(cfg.act)
    h = x @ p["w1"].astype(x.dtype)
    if cfg.gated:
        h = a(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = a(h)
    return h @ p["w2"].astype(x.dtype)


def _moe_dispatch_local(x2d, p, cfg: TransformerConfig, e_loc: int, e0,
                        psum_axis: Optional[str]):
    """Grouped-GEMM MoE over the local expert shard.

    x2d: (T, d) local tokens (replicated over the model axis); expert
    weights hold e_loc experts starting at global id e0.
    """
    moe = cfg.moe
    T, d = x2d.shape
    E, K = moe.n_experts, moe.top_k
    a = act_fn(cfg.act)
    logits = (x2d @ p["router"].astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                    # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    flat_e = topi.reshape(-1)                                # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within expert group
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - starts[se]
    C = int(max(1, -(-T * K * moe.capacity_factor // E)))
    local = (se >= e0) & (se < e0 + e_loc)
    keep = (pos < C) & local
    le = jnp.where(keep, se - e0, 0)
    lp = jnp.where(keep, pos, C - 1)
    xt = x2d[st] * keep[:, None].astype(x2d.dtype)
    buf = jnp.zeros((e_loc, C, d), x2d.dtype)
    buf = buf.at[le, lp].add(xt)                             # (e_loc, C, d)
    # weights are already the local expert shard (shard_map) or full (E=e_loc)
    w1 = p["we1"].astype(x2d.dtype)
    w3 = p["we3"].astype(x2d.dtype)
    w2 = p["we2"].astype(x2d.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    h = a(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
    y = jnp.einsum("ecf,efd->ecd", h, w2)                    # (e_loc, C, d)
    yt = y[le, lp] * (keep[:, None] * sw[:, None]).astype(x2d.dtype)
    out = jax.ops.segment_sum(yt, st, num_segments=T)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out


def moe_ffn(x, p, cfg: TransformerConfig, ctx: ShardCtx):
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    moe = cfg.moe
    if ctx.mesh is None:
        out = _moe_dispatch_local(x2d, p, cfg, moe.n_experts, 0, None)
    else:
        from jax.sharding import PartitionSpec
        ma = ctx.model_axis
        n_model = ctx.mesh.shape[ma]
        e_loc = moe.n_experts // n_model
        ew = PartitionSpec(ma)    # expert-major weights sharded over model
        rp = PartitionSpec()      # replicated

        def inner(x2d_loc, router, we1, we3, we2):
            pp = {"router": router, "we1": we1, "we3": we3, "we2": we2}
            e0 = jax.lax.axis_index(ma) * e_loc
            return _moe_dispatch_local(x2d_loc, pp, cfg, e_loc, e0, ma)

        out = jax.shard_map(
            inner, mesh=ctx.mesh,
            in_specs=(PartitionSpec(ctx.data_axes), rp, ew, ew, ew),
            out_specs=PartitionSpec(ctx.data_axes),
            check_vma=False,
        )(x2d, p["router"], p["we1"], p["we3"], p["we2"])
    if moe.n_shared:
        a = act_fn(cfg.act)
        h = a(x2d @ p["ws1"].astype(x.dtype)) * (x2d @ p["ws3"].astype(x.dtype))
        out = out + h @ p["ws2"].astype(x.dtype)
    return out.reshape(B, S, d)


# ---------------------------------------------------------------------------
# layers / forward
# ---------------------------------------------------------------------------

def _layer(x, p, cfg: TransformerConfig, ctx: ShardCtx, kind: str):
    B, S, d = x.shape
    p = _gather_layer(p, ctx)
    h = rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    pos = jnp.arange(S)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    window = cfg.local_window if kind == "local" else None
    o = chunked_attention(q, k, v, causal=True, window=window,
                          q_block=cfg.q_block,
                          unroll_blocks=cfg.analysis_unroll)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    h = rms_norm(x, p["ln2"])
    if cfg.moe:
        x = x + moe_ffn(h, p, cfg, ctx)
    else:
        x = x + dense_ffn(h, p, cfg)
    return x


def forward_hidden(params, tokens, cfg: TransformerConfig,
                   ctx: ShardCtx = ShardCtx()):
    """tokens (B, S) -> final hidden states (B, S, d)."""
    x = params["embed"][tokens].astype(cfg.dtype)
    for kind, count in cfg.layer_groups:
        stack = params["groups"][kind]

        def body(carry, lp, _kind=kind):
            fn = functools.partial(_layer, cfg=cfg, ctx=ctx, kind=_kind)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(carry, lp), None

        x, _ = jax.lax.scan(body, x, stack,
                            unroll=count if cfg.analysis_unroll else 1)
    return rms_norm(x, params["final_ln"])


def forward(params, tokens, cfg: TransformerConfig, ctx: ShardCtx = ShardCtx()):
    """tokens (B, S) -> logits (B, S, vocab) (small-vocab / test use)."""
    x = forward_hidden(params, tokens, cfg, ctx)
    return jnp.einsum("bsd,dv->bsv", x,
                      params["head"].astype(x.dtype)).astype(jnp.float32)


def loss_fn(params, batch, cfg: TransformerConfig, ctx: ShardCtx = ShardCtx(),
            loss_chunk: int = 1024):
    """Causal LM loss with sequence-chunked head+CE.

    The (B, S, vocab) logits tensor is never materialized: the head matmul
    and log-softmax run per sequence chunk under a rematerialized scan
    (crucial for the 256k-vocab archs at 4k train / 32k prefill shapes).
    """
    x = forward_hidden(params, batch["tokens"], cfg, ctx)
    labels = batch["labels"]
    B, S, d = x.shape
    ck = min(loss_chunk, S)
    nchunk = S // ck if S % ck == 0 else -(-S // ck)
    pad = nchunk * ck - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    xc = x.reshape(B, nchunk, ck, d).swapaxes(0, 1)
    lc = labels.reshape(B, nchunk, ck).swapaxes(0, 1)
    head = params["head"]

    @jax.checkpoint
    def chunk_body(carry, inp):
        nll_sum, n_tok = carry
        xc_i, lb_i = inp
        logits = jnp.einsum("bsd,dv->bsv", xc_i,
                            head.astype(xc_i.dtype)).astype(jnp.float32)
        mask = (lb_i >= 0).astype(jnp.float32)
        lbl = jnp.maximum(lb_i, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        nll = ((logz - gold) * mask).sum()
        return (nll_sum + nll, n_tok + mask.sum()), None

    (nll_sum, n_tok), _ = jax.lax.scan(
        chunk_body, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc),
        unroll=nchunk if cfg.analysis_unroll else 1)
    return nll_sum / jnp.maximum(n_tok, 1.0)


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-group KV caches
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Per-group KV caches; local groups keep only a window-sized buffer."""
    cache = {}
    for kind, count in cfg.layer_groups:
        S = cfg.local_window if kind == "local" else max_len
        S = min(S, max_len)
        shape = (count, batch, S, cfg.n_kv_heads, cfg.d_head)
        cache[kind] = {"k": jnp.zeros(shape, cfg.dtype),
                       "v": jnp.zeros(shape, cfg.dtype)}
    return cache


def decode_step(params, cache, tokens, lengths, cfg: TransformerConfig,
                ctx: ShardCtx = ShardCtx()):
    """One decode step. tokens: (B, 1) new token; lengths: (B,) cache fill.

    Returns (logits (B, vocab), updated cache).
    """
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)     # (B,1,d)
    new_cache = {}
    for kind, count in cfg.layer_groups:
        stack = params["groups"][kind]
        kc, vc = cache[kind]["k"], cache[kind]["v"]
        Sc = kc.shape[2]
        window = cfg.local_window if kind == "local" else None

        def body(carry, layer_in, _kind=kind, _Sc=Sc, _window=window):
            x = carry
            lp, kci, vci = layer_in
            lp = _gather_layer(lp, ctx)
            h = rms_norm(x, lp["ln1"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
            q = apply_rope(q, lengths[:, None], cfg.rope_theta)
            k = apply_rope(k, lengths[:, None], cfg.rope_theta)
            slot = lengths if _window is None else lengths % _Sc
            bidx = jnp.arange(B)
            kci = kci.at[bidx, slot].set(k[:, 0])
            vci = vci.at[bidx, slot].set(v[:, 0])
            eff_len = jnp.minimum(lengths + 1, _Sc)
            o = decode_attention(q, kci, vci, eff_len, window=_window)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
            h = rms_norm(x, lp["ln2"])
            if cfg.moe:
                x = x + moe_ffn(h, lp, cfg, ctx)
            else:
                x = x + dense_ffn(h, lp, cfg)
            return x, (kci, vci)

        x, (kc_new, vc_new) = jax.lax.scan(
            body, x, (stack, kc, vc),
            unroll=count if cfg.analysis_unroll else 1)
        new_cache[kind] = {"k": kc_new, "v": vc_new}
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    return logits[:, 0].astype(jnp.float32), new_cache


def prefill(params, tokens, cfg: TransformerConfig, max_len: int,
            ctx: ShardCtx = ShardCtx()):
    """Full-sequence forward that also fills the KV cache.

    Returns (logits (B, S, vocab), cache).  The cache write replays the
    k/v projections (cheap relative to attention itself).
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = params["embed"][tokens].astype(cfg.dtype)
    for kind, count in cfg.layer_groups:
        stack = params["groups"][kind]
        Sc = cache[kind]["k"].shape[2]

        def kv_of_layer(carry, lp, _kind=kind):
            x = carry
            lp = _gather_layer(lp, ctx)
            h = rms_norm(x, lp["ln1"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
            k = apply_rope(k, jnp.arange(S)[None, :], cfg.rope_theta)
            if ctx.mesh is not None:
                # keep the stacked per-layer KV sharded while it flows
                # through the scan (otherwise the (L,B,S,H,D) stack
                # materializes replicated before the cache write)
                from jax.sharding import NamedSharding, PartitionSpec
                kv_ax = "model" if cfg.n_kv_heads % int(
                    ctx.mesh.shape[ctx.model_axis]) == 0 else None
                ns = NamedSharding(ctx.mesh, PartitionSpec(
                    ctx.data_axes, None if kv_ax else "model", kv_ax, None))
                k = jax.lax.with_sharding_constraint(k, ns)
                v = jax.lax.with_sharding_constraint(v, ns)
            x = _layer(x, lp, cfg, ctx, _kind)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(
            kv_of_layer, x, stack,
            unroll=count if cfg.analysis_unroll else 1)
        take = min(Sc, S)
        # rolling-buffer contract: token position p lives in slot p % Sc
        # (decode evicts position p-Sc when writing p; prefill must agree)
        positions = np.arange(S - take, S)
        slots = positions % Sc
        cache[kind]["k"] = cache[kind]["k"].at[:, :, slots].set(
            ks[:, :, S - take:])
        cache[kind]["v"] = cache[kind]["v"].at[:, :, slots].set(
            vs[:, :, S - take:])
    x = rms_norm(x, params["final_ln"])
    last = jnp.einsum("bd,dv->bv", x[:, -1],
                      params["head"].astype(x.dtype)).astype(jnp.float32)
    return last, cache
