"""DCN-v2 (arXiv:2008.13535) with a hand-built EmbeddingBag.

JAX has no nn.EmbeddingBag / CSR: the bag lookup is ``jnp.take`` over the
row-sharded table + masked ``jax.ops.segment_sum`` (the assignment makes
this primitive part of the system).  Single-valued categorical fields are
the bag-size-1 special case of the same code path.

Shapes:
  dense   (B, n_dense) float
  sparse  (B, n_sparse, bag) int32 indices into per-field vocab (padded -1)
The embedding table is one (n_sparse * vocab, dim) matrix, row-sharded over
the "model" mesh axis; field f row-offset = f * vocab.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import apply_mlp, dense_init, init_mlp, normal_init


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1_000_000       # rows per field
    embed_dim: int = 16
    n_cross: int = 3
    mlp_dims: tuple = (1024, 1024, 512)
    bag: int = 1                 # multi-hot bag size per field

    @property
    def d_x0(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init_dcn(key, cfg: DCNConfig):
    keys = jax.random.split(key, 6 + cfg.n_cross)
    d = cfg.d_x0
    params = {
        "table": normal_init(keys[0], (cfg.n_sparse * cfg.vocab,
                                       cfg.embed_dim), 0.01),
        "cross": [],
        "mlp": init_mlp(keys[1], [d, *cfg.mlp_dims]),
        "head": dense_init(keys[2], cfg.mlp_dims[-1] + d, 1),
    }
    for i in range(cfg.n_cross):
        params["cross"].append({
            "w": dense_init(keys[3 + i], d, d),
            "b": jnp.zeros((d,), jnp.float32),
        })
    return params


def embedding_bag(table, indices, field_offsets, mode: str = "sum"):
    """table: (R, dim); indices: (B, F, bag) with -1 padding.

    Returns (B, F, dim).  jnp.take + masked mean/sum -- the EmbeddingBag.
    """
    B, F, bag = indices.shape
    mask = (indices >= 0)
    flat = (jnp.maximum(indices, 0) + field_offsets[None, :, None]).reshape(-1)
    emb = jnp.take(table, flat, axis=0).reshape(B, F, bag, -1)
    emb = emb * mask[..., None].astype(emb.dtype)
    out = emb.sum(axis=2)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=2, keepdims=False)[..., None],
                                1.0)
    return out


def dcn_forward(params, dense, sparse, cfg: DCNConfig):
    """Returns logits (B,)."""
    B = dense.shape[0]
    offs = jnp.arange(cfg.n_sparse, dtype=jnp.int32) * cfg.vocab
    emb = embedding_bag(params["table"], sparse, offs)       # (B, F, dim)
    x0 = jnp.concatenate([dense, emb.reshape(B, -1)], axis=-1)
    x = x0
    for c in params["cross"]:                                # DCN-v2 cross
        x = x0 * (x @ c["w"] + c["b"]) + x
    deep = apply_mlp(params["mlp"], x0, act="relu", final_act=True)
    feat = jnp.concatenate([x, deep], axis=-1)
    return (feat @ params["head"])[:, 0]


def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# retrieval scoring: one query against n_candidates (batched dot + top-k)
# ---------------------------------------------------------------------------

def retrieval_scores(params, dense, sparse, cand_embs, cfg: DCNConfig,
                     topk: int = 100):
    """Score 1M candidates for each query via the deep tower's final layer.

    cand_embs: (n_cand, d_tower). Returns (values, indices) top-k.
    """
    B = dense.shape[0]
    offs = jnp.arange(cfg.n_sparse, dtype=jnp.int32) * cfg.vocab
    emb = embedding_bag(params["table"], sparse, offs)
    x0 = jnp.concatenate([dense, emb.reshape(B, -1)], axis=-1)
    q = apply_mlp(params["mlp"], x0, act="relu", final_act=True)  # (B, dt)
    scores = q @ cand_embs.T                                  # (B, n_cand)
    return jax.lax.top_k(scores, topk)
