"""Model substrate: LM transformer family, GNNs, equivariant GNN, recsys."""
