"""GNN substrate: segment_sum message passing + GIN / MeshGraphNet / EGNN.

JAX has no sparse message-passing primitive (BCOO only): we implement it as
gather -> edge compute -> ``jax.ops.segment_sum`` scatter over an edge index,
as the assignment requires.  The same primitive powers the on-device truss
support computation of the clique engine (edge support = triangle messages).

Graphs arrive as fixed-shape padded batches:
  nodes  (N, d_feat)  float
  edges  (2, E) int32 (src, dst), padded with N-1 self loops + edge_mask
  edge_mask (E,) float {0,1}
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import apply_mlp, init_mlp, layer_norm


def scatter_sum(messages, dst, num_nodes):
    return jax.ops.segment_sum(messages, dst, num_segments=num_nodes)


def scatter_mean(messages, dst, num_nodes):
    s = jax.ops.segment_sum(messages, dst, num_segments=num_nodes)
    c = jax.ops.segment_sum(jnp.ones((messages.shape[0], 1),
                                     messages.dtype), dst,
                            num_segments=num_nodes)
    return s / jnp.maximum(c, 1.0)


def scatter_max(messages, dst, num_nodes):
    return jax.ops.segment_max(messages, dst, num_segments=num_nodes,
                               indices_are_sorted=False)


# ---------------------------------------------------------------------------
# GIN (arXiv:1810.00826): h' = MLP((1+eps) h + sum_j h_j)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GINConfig:
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 0            # input feature dim
    n_classes: int = 2
    graph_level: bool = False  # sum-pool readout over graph_ids


def init_gin(key, cfg: GINConfig):
    params = {"eps": jnp.zeros((cfg.n_layers,), jnp.float32), "layers": []}
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        key, k = jax.random.split(key)
        params["layers"].append({
            "mlp": init_mlp(k, [d_prev, cfg.d_hidden, cfg.d_hidden]),
            "ln": {"scale": jnp.ones((cfg.d_hidden,), jnp.float32),
                   "bias": jnp.zeros((cfg.d_hidden,), jnp.float32)},
        })
        d_prev = cfg.d_hidden
    key, k = jax.random.split(key)
    params["head"] = init_mlp(k, [cfg.d_hidden, cfg.n_classes])
    return params


def _id_constrain(x, kind):
    return x


def gin_forward(params, nodes, edges, edge_mask, cfg: GINConfig,
                graph_ids: Optional[jax.Array] = None,
                n_graphs: int = 1, wsc=_id_constrain):
    h = nodes
    src, dst = edges[0], edges[1]
    N = h.shape[0]

    def one_layer(h, layer, eps):
        msg = wsc(h[src], "edges") * edge_mask[:, None]
        agg = wsc(scatter_sum(msg, dst, N), "nodes")
        h = (1.0 + eps) * h + agg
        h = apply_mlp(layer["mlp"], h, act="relu", final_act=True)
        return layer_norm(h, layer["ln"]["scale"], layer["ln"]["bias"])

    for i, layer in enumerate(params["layers"]):
        # remat per MP layer: full-batch graphs (60M+ edges) cannot keep
        # per-layer edge messages alive for the backward pass
        h = jax.checkpoint(one_layer)(h, layer, params["eps"][i])
    if cfg.graph_level:
        assert graph_ids is not None
        pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
        return apply_mlp(params["head"], pooled)
    return apply_mlp(params["head"], h)


# ---------------------------------------------------------------------------
# MeshGraphNet (arXiv:2010.03409): encode-process-decode, residual MP
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 0
    d_edge_in: int = 0
    d_out: int = 3
    scan_layers: bool = False  # lax.scan over stacked blocks: XLA reuses
    #   per-layer buffers across iterations (python-unrolled layers kept
    #   ~5 GB/layer of temps alive on 60M-edge graphs)


def _mgn_mlp_dims(cfg: MGNConfig, d_in: int):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def init_mgn(key, cfg: MGNConfig):
    key, k1, k2, k3 = jax.random.split(key, 4)
    params = {
        "node_enc": init_mlp(k1, _mgn_mlp_dims(cfg, cfg.d_node_in)),
        "edge_enc": init_mlp(k2, _mgn_mlp_dims(cfg, cfg.d_edge_in)),
        "decoder": init_mlp(k3, [cfg.d_hidden, cfg.d_hidden, cfg.d_out]),
        "blocks": [],
    }
    for _ in range(cfg.n_layers):
        key, ke, kn = jax.random.split(key, 3)
        params["blocks"].append({
            "edge": init_mlp(ke, _mgn_mlp_dims(cfg, 3 * cfg.d_hidden)),
            "node": init_mlp(kn, _mgn_mlp_dims(cfg, 2 * cfg.d_hidden)),
        })
    return params


def mgn_forward(params, nodes, edge_feats, edges, edge_mask, cfg: MGNConfig,
                wsc=_id_constrain):
    src, dst = edges[0], edges[1]
    N = nodes.shape[0]
    h = apply_mlp(params["node_enc"], nodes, act="relu", final_act=True)
    e = apply_mlp(params["edge_enc"], edge_feats, act="relu", final_act=True)

    def one_block(h, e, blk):
        e_in = jnp.concatenate([e, wsc(h[src], "edges"),
                                wsc(h[dst], "edges")], axis=-1)
        e = wsc(e + apply_mlp(blk["edge"], e_in, act="relu",
                              final_act=True), "edges")
        agg = wsc(scatter_sum(e * edge_mask[:, None], dst, N), "nodes")
        h = wsc(h + apply_mlp(blk["node"], jnp.concatenate([h, agg], -1),
                              act="relu", final_act=True), "nodes")
        return h, e

    if cfg.scan_layers:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["blocks"])

        def body(carry, blk):
            h, e = carry
            h, e = jax.checkpoint(one_block)(h, e, blk)
            return (h, e), None

        (h, e), _ = jax.lax.scan(body, (h, e), stacked)
    else:
        for blk in params["blocks"]:
            h, e = jax.checkpoint(one_block)(h, e, blk)
    return apply_mlp(params["decoder"], h)


# ---------------------------------------------------------------------------
# EGNN (arXiv:2102.09844): E(n)-equivariant message passing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 0
    d_out: int = 1


def init_egnn(key, cfg: EGNNConfig):
    key, k0 = jax.random.split(key)
    params = {"embed": init_mlp(k0, [cfg.d_in, cfg.d_hidden]), "layers": []}
    d = cfg.d_hidden
    for _ in range(cfg.n_layers):
        key, ke, kx, kh = jax.random.split(key, 4)
        params["layers"].append({
            "phi_e": init_mlp(ke, [2 * d + 1, d, d]),
            "phi_x": init_mlp(kx, [d, d, 1]),
            "phi_h": init_mlp(kh, [2 * d, d, d]),
        })
    key, kh = jax.random.split(key)
    params["head"] = init_mlp(kh, [d, cfg.d_out])
    return params


def egnn_forward(params, h0, x0, edges, edge_mask, cfg: EGNNConfig,
                 graph_ids: Optional[jax.Array] = None, n_graphs: int = 1,
                 wsc=_id_constrain):
    """h0: (N, d_in) invariant feats; x0: (N, 3) coordinates.

    Returns (out, x): invariant per-graph (or per-node) output + updated
    equivariant coordinates.
    """
    src, dst = edges[0], edges[1]
    N = h0.shape[0]
    h = apply_mlp(params["embed"], h0)
    x = x0

    def one_layer(h, x, layer):
        dx = wsc(x[src] - x[dst], "edges")
        d2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
        m_in = jnp.concatenate([wsc(h[src], "edges"), wsc(h[dst], "edges"),
                                d2], axis=-1)
        m = apply_mlp(layer["phi_e"], m_in, act="silu", final_act=True)
        m = m * edge_mask[:, None]
        w = apply_mlp(layer["phi_x"], m, act="silu")        # (E, 1)
        coef = w / jnp.maximum(jnp.sqrt(d2), 1.0)
        x = wsc(x + scatter_mean(dx * coef * edge_mask[:, None], dst, N),
                "nodes")
        agg = wsc(scatter_sum(m, dst, N), "nodes")
        h = h + apply_mlp(layer["phi_h"],
                          jnp.concatenate([h, agg], -1), act="silu",
                          final_act=True)
        return h, x

    for layer in params["layers"]:
        h, x = jax.checkpoint(one_layer)(h, x, layer)
    if graph_ids is not None:
        pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
        return apply_mlp(params["head"], pooled), x
    return apply_mlp(params["head"], h), x
