"""Shared model building blocks (pure functional, no flax)."""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, stddev, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    """LeCun-normal weight for a (d_in, d_out) matmul."""
    return normal_init(key, (d_in, d_out), (1.0 / d_in) ** 0.5, dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def act_fn(name: str) -> Callable:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "squared_relu":  # Primer / Nemotron-4
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def init_mlp(key, dims: Sequence[int], dtype=jnp.float32):
    """Plain MLP params: list of (W, b)."""
    params = []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        params.append({
            "w": dense_init(k, dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return params


def apply_mlp(params, x, act: str = "relu", final_act: bool = False):
    fn = act_fn(act)
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = fn(x)
    return x


def rope_frequencies(d_head: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, z_loss: float = 0.0):
    """Mean token cross-entropy in f32; labels -100 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * ((logz * mask) ** 2).sum() / jnp.maximum(
            mask.sum(), 1.0)
    return loss
