"""Request, ticket, and admission-queue primitives of the serving tier.

DESIGN.md section 10.  A :class:`Request` is one admitted ``(graph, k,
mode)`` query plus its delivery state: a per-request sequence space (one
sequence number per pulled tile chunk) and a reorder buffer that releases
decoded rows to the request's sink strictly in pull order.  That sequencer
is what lets the :class:`~repro.serve.scheduler.BatchScheduler` fuse
chunks from *different* requests into shared device batches -- and even
complete them out of order across size bins -- while every individual
request still observes exactly the row order of a serial
``stream_cliques`` run (the per-request determinism invariant).

Thread model: sequence numbers are assigned by the scheduler thread at
pull time; deliveries arrive from the scheduler thread (host-spilled
tiles, counts harvested inline) and from the dispatcher decode worker
(listing triples).  A per-request lock serializes them; the waiting
client thread only ever blocks on the resolution event.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Dict, Optional

import numpy as np

from ..core import listing
from ..core.engine_np import Stats
from ..obs import trace
from ..resilience import retry as fault_retry

#: process-wide ticket-id source; the id keys the request's async span
#: tree in exported traces and is stable for the request's lifetime
_RID = itertools.count(1)

#: early-termination threshold baked into the serving tier (the engines'
#: default); per-request et knobs would forbid cross-request batch fusion
ET_T = 3


class ServiceOverloaded(RuntimeError):
    """Admission rejected: the request queue is full (backpressure)."""


class ServiceClosed(RuntimeError):
    """Submitted to (or queued on) a service that has been closed."""


class DeadlineExceeded(RuntimeError):
    """A deadline-*enforced* request was cancelled at its deadline.

    Raised out of :meth:`Ticket.result` for requests submitted with
    ``enforce_deadline=True`` whose deadline expired before completion.
    Carries whatever had already been delivered in pull order:
    ``partial_rows`` (listing mode with the default in-memory sink; None
    otherwise), ``emitted`` (rows the sink accepted), and
    ``partial_count`` (count mode's running sum).  Requests *without*
    enforcement keep the accounting-only contract (late but exact,
    ``deadline_missed=True``).
    """

    def __init__(self, msg: str, *, partial_rows=None, emitted: int = 0,
                 partial_count: Optional[int] = None) -> None:
        super().__init__(msg)
        self.partial_rows = partial_rows
        self.emitted = emitted
        self.partial_count = partial_count


def apply_vertex_filter(rows: np.ndarray, vertex: int) -> np.ndarray:
    """Keep only clique rows containing ``vertex``.

    The single definition of vertex-filter semantics, shared by the
    service delivery path, the load generator's oracle, and the tests --
    so "byte-identical to serial" is checkable against one function.
    """
    if rows.shape[0] == 0:
        return rows
    return rows[(rows == vertex).any(axis=1)]


@dataclasses.dataclass
class RequestResult:
    """Terminal state of one request, returned by :meth:`Ticket.result`.

    ``count`` is the exact clique count (count mode; None for listing),
    ``rows`` the ``(n, k)`` int64 clique array (listing mode with the
    default in-memory sink; None when the caller supplied its own sink),
    ``emitted`` the rows accepted by the sink, ``latency_s`` the
    admission-to-resolution wall clock, and ``deadline_missed`` whether
    that exceeded the request's deadline (deadlines are accounting, not
    cancellation: a late request still completes exactly).  ``stats``
    carries the per-request engine accounting (spills, overflows, ...).
    """

    kind: str
    count: Optional[int] = None
    rows: Optional[np.ndarray] = None
    emitted: int = 0
    latency_s: float = 0.0
    deadline_s: Optional[float] = None
    deadline_missed: bool = False
    stats: Optional[Stats] = None
    # per-stage latency breakdown: "queue" (wait before admission),
    # "fuse" (buffer wait), "device" (flush-to-delivery, overlapping
    # across fused requests), "reorder" (sequencer park time)
    stage_s: Dict[str, float] = dataclasses.field(default_factory=dict)


class Request:
    """One admitted query plus its sequencer/delivery state.

    Built by :meth:`CliqueService.submit`; client code holds the
    :class:`Ticket`, the scheduler and decode worker call
    :meth:`next_seq` / :meth:`deliver` / :meth:`finish_feeding`.

    ``mode`` is ``"count"``, ``"list"``, or ``"delta"``.  Listing and
    delta requests deliver into ``sink`` (default: an in-memory
    ``ArraySink`` honoring ``max_out``) after ``vertex_filter`` (keep
    rows containing that vertex) is applied; ``max_out`` truncation
    happens *after* filtering.  A delta request ("cliques gained since
    version N") carries ``since_version`` and is answered from the
    graph's :class:`~repro.delta.PlanIndex` lineage on the scheduler
    thread, streaming through the same sequencer/sink machinery.
    ``enforce_deadline=True`` arms cooperative cancellation: the
    scheduler stops feeding the request at ``deadline_s`` and resolves it
    with :class:`DeadlineExceeded` instead of finishing late.
    """

    def __init__(
        self,
        g,
        k: int,
        mode: str = "count",
        *,
        order: str = "hybrid",
        use_rule2: bool = True,
        vertex_filter: Optional[int] = None,
        max_out: Optional[int] = None,
        deadline_s: Optional[float] = None,
        enforce_deadline: bool = False,
        sink: Optional[listing.CliqueSink] = None,
        since_version: Optional[int] = None,
    ) -> None:
        if mode not in ("count", "list", "delta"):
            raise ValueError(
                f"mode must be 'count', 'list', or 'delta', got {mode!r}")
        if order not in ("truss", "hybrid", "color"):
            raise ValueError(f"unknown edge-tile mode: {order}")
        if mode in ("list", "delta") and k < 3:
            raise ValueError(f"{mode} mode requires k >= 3")
        if mode == "delta" and since_version is None:
            raise ValueError("delta mode requires since_version")
        if since_version is not None and since_version < 0:
            raise ValueError("since_version must be >= 0")
        if k < 1:
            raise ValueError("k must be >= 1")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be positive")
        if enforce_deadline and deadline_s is None:
            raise ValueError("enforce_deadline requires deadline_s")
        self.g = g
        self.k = int(k)
        self.l = self.k - 2
        self.mode = mode
        self.order = order
        self.use_rule2 = use_rule2
        self.vertex_filter = vertex_filter
        self.max_out = max_out
        self.deadline_s = deadline_s
        self.enforce_deadline = bool(enforce_deadline)
        self.since_version = since_version
        self.stats = Stats()
        self.rid = next(_RID)  # ticket id; keys the request's trace tree
        self.stage_s: Dict[str, float] = {}
        self._stage_lock = threading.Lock()
        self.submit_t: Optional[float] = None  # monotonic, set at admission
        self.deadline_t: Optional[float] = None  # absolute monotonic
        self._external_sink = sink is not None
        if mode != "count":
            self._sink = sink if sink is not None else listing.ArraySink(
                self.k, max_out=max_out)
        else:
            self._sink = None
        self._count = 0
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._seq_next = 0      # next sequence number to assign (scheduler)
        self._release_next = 0  # next sequence number to release to the sink
        self._parked: dict = {}  # seq -> rows awaiting in-order release
        self._delivered = 0
        self._feeding_done = False
        self._result: Optional[RequestResult] = None
        self._error: Optional[BaseException] = None
        self._on_done = None  # service hook, set at admission
        self._on_isolated = None  # scheduler hook: count contained failures
        self._delta_entry = None  # service graph-registry entry (delta mode)

    # -- scheduler-side API -------------------------------------------------

    def mark_submitted(self, now: Optional[float] = None) -> None:
        """Stamp admission time; deadlines become absolute from here."""
        self.submit_t = time.monotonic() if now is None else now
        if self.deadline_s is not None:
            self.deadline_t = self.submit_t + self.deadline_s
        trace.async_begin("request", id=self.rid, k=self.k, mode=self.mode)

    def mark_admitted(self, now: Optional[float] = None) -> None:
        """Stamp scheduler pickup; the queue wait becomes attributable."""
        now = time.monotonic() if now is None else now
        if self.submit_t is not None:
            self.add_stage("queue", now - self.submit_t)
        trace.async_instant("request/admit", id=self.rid)

    def add_stage(self, stage: str, dt: float) -> None:
        """Accrue ``dt`` seconds to one lifecycle stage (thread-safe)."""
        with self._stage_lock:
            self.stage_s[stage] = self.stage_s.get(stage, 0.0) + dt

    def next_seq(self) -> int:
        """Assign the next chunk sequence number (scheduler thread only)."""
        s = self._seq_next
        self._seq_next += 1
        return s

    @property
    def full(self) -> bool:
        """True once the sink stopped accepting (listing early stop)."""
        return self._sink is not None and self._sink.full

    def deliver(self, seq: int, payload) -> None:
        """Deliver one completed chunk (count int or decoded row array).

        Thread-safe; called from the scheduler thread (spills, routed
        counts) and the decode worker (routed listing chunks).  Listing
        payloads park in the reorder buffer until every earlier sequence
        number has been released, so the sink observes strict pull order
        no matter which fused batch finished first.
        """
        with self._lock:
            if self._event.is_set():
                return  # already resolved (failed/cancelled): drop late work
            if self.mode == "count":
                self._count += int(payload)
                self._delivered += 1
            else:
                self._parked[seq] = (payload, time.perf_counter_ns())
                while self._release_next in self._parked:
                    rows, t_park = self._parked.pop(self._release_next)
                    dur_ns = time.perf_counter_ns() - t_park
                    with self._stage_lock:
                        self.stage_s["reorder"] = (
                            self.stage_s.get("reorder", 0.0) + dur_ns / 1e9
                        )
                    trace.complete(
                        "reorder/park", t_park, dur_ns,
                        rid=self.rid, seq=self._release_next,
                    )
                    self._release_next += 1
                    self._delivered += 1
                    try:
                        self._emit_locked(rows)
                    except Exception as exc:
                        # a raising sink fails only this request -- the
                        # scheduler and every other in-flight request
                        # keep running (per-request isolation)
                        self._fail_locked(exc)
                        if self._on_isolated is not None:
                            self._on_isolated(self, exc)
                        return
            self._maybe_resolve_locked()

    def finish_feeding(self) -> None:
        """Signal that no further sequence numbers will be assigned."""
        with self._lock:
            self._feeding_done = True
            self._maybe_resolve_locked()

    def fail(self, exc: BaseException) -> None:
        """Resolve the request exceptionally (admission/scheduler error)."""
        with self._lock:
            self._fail_locked(exc)

    def cancel_deadline(self, now: Optional[float] = None) -> bool:
        """Cancel a deadline-enforced request that blew its deadline.

        Called by the scheduler once ``deadline_t`` has passed for a
        request with ``enforce_deadline=True``.  Resolves the ticket with
        :class:`DeadlineExceeded` carrying whatever was already released
        in pull order (partial rows / running count).  Returns False when
        the request had already resolved (benign race with completion).
        """
        with self._lock:
            if self._event.is_set():
                return False
            partial = None
            emitted = 0
            pcount = None
            if self.mode == "count":
                pcount = self._count
            elif self._sink is not None:
                try:
                    self._sink.close()
                except Exception:
                    pass  # a failing sink must not block cancellation
                emitted = self._sink.accepted
                if not self._external_sink:
                    partial = self._sink.result()
            self._fail_locked(DeadlineExceeded(
                f"deadline {self.deadline_s}s exceeded",
                partial_rows=partial, emitted=emitted, partial_count=pcount))
            return True

    # -- internals ----------------------------------------------------------

    def _fail_locked(self, exc: BaseException) -> None:
        if self._event.is_set():
            return
        self._error = exc
        trace.async_end("request", id=self.rid, error=repr(exc))
        self._event.set()

    def _emit_locked(self, rows: np.ndarray) -> None:
        fault_retry.consume("sink.write")  # chaos site: delivery-side emit
        if self.vertex_filter is not None:
            rows = apply_vertex_filter(rows, self.vertex_filter)
        accepted = self._sink.emit(rows)
        self.stats.emitted_cliques += accepted

    def _maybe_resolve_locked(self) -> None:
        if self._event.is_set():
            return
        if not (self._feeding_done and self._delivered == self._seq_next):
            return
        now = time.monotonic()
        latency = now - self.submit_t if self.submit_t is not None else 0.0
        missed = self.deadline_t is not None and now > self.deadline_t
        rows = None
        emitted = 0
        if self.mode != "count":
            self._sink.close()
            emitted = self._sink.accepted
            self.stats.sink_bytes += self._sink.bytes_written
            if not self._external_sink:
                rows = self._sink.result()
        with self._stage_lock:
            stage_s = dict(self.stage_s)
        self._result = RequestResult(
            kind=self.mode,
            count=self._count if self.mode == "count" else None,
            rows=rows,
            emitted=emitted,
            latency_s=latency,
            deadline_s=self.deadline_s,
            deadline_missed=missed,
            stats=self.stats,
            stage_s=stage_s,
        )
        trace.async_end(
            "request", id=self.rid,
            latency_ms=round(latency * 1e3, 3),
            deadline_missed=missed,
        )
        self._event.set()
        if self._on_done is not None:
            self._on_done(self._result)


class Ticket:
    """Client-side handle of a submitted request (future-like).

    Returned by :meth:`CliqueService.submit`; safe to wait on from any
    thread.  By default deadlines never cancel work -- a late request
    resolves with ``deadline_missed=True`` and exact results.  With
    ``enforce_deadline=True`` an expired request instead resolves with
    :class:`DeadlineExceeded` (carrying any partial results) while the
    service keeps serving everyone else.
    """

    def __init__(self, request: Request) -> None:
        self._request = request

    def done(self) -> bool:
        """True once the request has resolved (result or error)."""
        return self._request._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Block for the terminal :class:`RequestResult`.

        Raises ``TimeoutError`` if the request does not resolve within
        ``timeout`` seconds, or re-raises the failure that resolved it
        exceptionally.
        """
        if not self._request._event.wait(timeout):
            raise TimeoutError("request not resolved within timeout")
        if self._request._error is not None:
            raise self._request._error
        return self._request._result


class RequestQueue:
    """Thread-safe bounded FIFO admission queue (the backpressure seam).

    ``put`` from any number of client threads; ``get`` from the
    scheduler thread.  A full queue makes non-blocking ``put`` raise
    :class:`ServiceOverloaded` (shed at the front door, before any
    per-request work), while ``block=True`` waits for capacity.  After
    :meth:`close`, ``put`` raises :class:`ServiceClosed` but queued
    requests still drain through ``get``.
    """

    def __init__(self, max_pending: int = 256) -> None:
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.max_pending = int(max_pending)
        self._dq: deque = deque()
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        """Number of queued (admitted but not yet scheduled) requests."""
        with self._cond:
            return len(self._dq)

    def put(self, req: Request, block: bool = True,
            timeout: Optional[float] = None) -> None:
        """Enqueue one request; overload behavior depends on ``block``.

        Raises :class:`ServiceOverloaded` immediately (``block=False``)
        or after ``timeout`` seconds without capacity; raises
        :class:`ServiceClosed` once the queue is closed.
        """
        with self._cond:
            if self._closed:
                raise ServiceClosed("service is closed")
            if len(self._dq) >= self.max_pending:
                if not block:
                    raise ServiceOverloaded(
                        f"queue full ({self.max_pending} pending)")
                ok = self._cond.wait_for(
                    lambda: self._closed or len(self._dq) < self.max_pending,
                    timeout)
                if self._closed:
                    raise ServiceClosed("service is closed")
                if not ok:
                    raise ServiceOverloaded(
                        f"queue full ({self.max_pending} pending) after "
                        f"{timeout}s")
            self._dq.append(req)
            self._cond.notify_all()

    def get(self, timeout: Optional[float] = None) -> Optional[Request]:
        """Dequeue the oldest request, blocking up to ``timeout`` seconds.

        Returns None on timeout or when the queue is closed and empty.
        """
        with self._cond:
            self._cond.wait_for(lambda: self._closed or self._dq, timeout)
            if not self._dq:
                return None
            req = self._dq.popleft()
            self._cond.notify_all()
            return req

    def get_nowait(self) -> Optional[Request]:
        """Dequeue the oldest request without blocking (None when empty)."""
        with self._cond:
            if not self._dq:
                return None
            req = self._dq.popleft()
            self._cond.notify_all()
            return req

    def close(self) -> None:
        """Stop admissions (``put`` raises); queued requests still drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
