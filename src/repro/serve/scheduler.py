"""Cross-request batch coalescing and EDF/LPT scheduling.

DESIGN.md section 10.  The :class:`BatchScheduler` is the heart of the
serving tier: it pulls small per-request tile chunks (the regular
``pipeline.stream_batches`` output, just with a small ``batch_size``)
from whichever active request EDF/LPT picks next, accumulates them in
per-``(mode, l, T)`` fuse buffers, and flushes each buffer as **one**
fused ``TileBatch`` through the shared multi-device dispatchers.  Because
the dispatcher pads every batch axis to a power of two
(``engine_jax.bucket_rows``), fused batches from any request mix land on
the same warm XLA executables as single-query traffic.

Coalescing rules (what may share a device batch):

* same ``mode`` (count vs list: different kernels),
* same ``l = k - 2`` (the kernels are specialized on l),
* same tile width ``T`` (fixed-shape batches).

Ordering/exactness: each pulled chunk carries its request's next
sequence number; counting segments are combined per-segment with the
exact int64 ``combine_counts`` (commutative -- no ordering needed), and
listing segments decode on the dispatcher's single FIFO decode worker
and release through the request's reorder buffer
(:meth:`~repro.serve.request.Request.deliver`), so per-request results
are byte-identical to a serial run regardless of how requests interleave.

All methods run on the service's scheduler thread; only the listing
route callbacks execute elsewhere (the dispatcher decode worker).
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import engine_jax, listing, pipeline
from ..core import tiles as tiles_mod
from ..core.engine_np import Stats
from ..obs import trace
from ..runtime.dispatch import Dispatcher, ListDispatcher, resolve_devices
from .request import ET_T, Request, ServiceOverloaded


@dataclasses.dataclass
class ServeStats:
    """Service-level accounting (all requests), updated under a lock.

    ``cross_request_batches`` counts fused device batches containing
    chunks from more than one request -- the direct evidence that
    continuous batching is happening; ``deadline_flushes`` counts fuse
    buffers flushed early because an owner's deadline drew near.

    Resilience counters: ``isolated_failures`` is requests resolved
    exceptionally while the service kept serving everyone else,
    ``deadline_cancels`` is deadline-*enforced* requests cooperatively
    cancelled at expiry, and ``shed`` is admissions rejected by the
    projected-deadline-miss load shedder.

    Dynamic-graph counters: ``graph_updates`` is applied edge batches
    (:meth:`~repro.serve.service.CliqueService.update_graph`) and
    ``delta_requests`` is admitted ``mode="delta"`` subscription reads.
    """

    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    deadline_missed: int = 0
    fused_batches: int = 0
    cross_request_batches: int = 0
    fused_rows: int = 0
    fused_chunks: int = 0
    deadline_flushes: int = 0
    spill_tiles: int = 0
    isolated_failures: int = 0
    deadline_cancels: int = 0
    shed: int = 0
    graph_updates: int = 0
    delta_requests: int = 0

    # every field is a monotonic total (repro.obs.metrics publication)
    _METRIC_KINDS = {f: "sum" for f in (
        "admitted", "rejected", "completed", "deadline_missed",
        "fused_batches", "cross_request_batches", "fused_rows",
        "fused_chunks", "deadline_flushes", "spill_tiles",
        "isolated_failures", "deadline_cancels", "shed",
        "graph_updates", "delta_requests",
    )}


def edf_pick(entries: List[Tuple[Optional[float], float, int]]
             ) -> Optional[int]:
    """Pick the next request to pull from: EDF with LPT fallback.

    ``entries`` holds ``(deadline_t, remaining_work, arrival_idx)`` per
    pullable request.  Earliest absolute deadline wins (requests without
    a deadline sort last, as infinitely patient); among equal deadlines
    the *largest* remaining work wins (LPT -- finishing long requests
    first maximizes batch-fusion opportunities for the stragglers and
    minimizes makespan), with arrival order as the final tie-break.
    Returns the index into ``entries`` or None when empty.
    """
    best = None
    best_key = None
    for i, (deadline, remaining, idx) in enumerate(entries):
        key = (deadline if deadline is not None else math.inf,
               -float(remaining), idx)
        if best_key is None or key < best_key:
            best, best_key = i, key
    return best


def fuse_chunks(chunks: List[Tuple[Request, int, pipeline.TileBatch]]
                ) -> Tuple[pipeline.TileBatch, List[tuple]]:
    """Concatenate same-(T) chunks into one fused batch plus segments.

    Returns ``(fused, segments)`` where each segment is
    ``(request, seq, row_start, row_stop, chunk_batch)`` -- the slice of
    the fused batch axis owned by that request's chunk.  A single chunk
    passes through unconcatenated.
    """
    if len(chunks) == 1:
        req, seq, b = chunks[0]
        return b, [(req, seq, 0, b.B, b)]
    T = chunks[0][2].T
    segments = []
    start = 0
    for req, seq, b in chunks:
        segments.append((req, seq, start, start + b.B, b))
        start += b.B
    fused = pipeline.TileBatch(
        T,
        np.concatenate([b.A for _, _, b in chunks]),
        np.concatenate([b.cand for _, _, b in chunks]),
        np.concatenate([b.sizes for _, _, b in chunks]),
        np.concatenate([b.nedges for _, _, b in chunks]),
        np.concatenate([b.anchors for _, _, b in chunks]),
        np.concatenate([b.verts for _, _, b in chunks]),
    )
    return fused, segments


@dataclasses.dataclass
class _ActiveStream:
    """One admitted request currently being pulled from."""

    req: Request
    stream: object  # pipeline.stream_batches generator
    remaining: int  # tiles not yet pulled (the LPT work estimate)
    idx: int        # arrival order (final tie-break)


class _FuseBuffer:
    """Accumulates same-(mode, l, T) chunks until flush."""

    def __init__(self, now: float) -> None:
        self.chunks: List[Tuple[Request, int, pipeline.TileBatch]] = []
        self.pull_ts: List[float] = []  # per-chunk buffer-entry times
        self.rows = 0
        self.created_t = now  # first-chunk time: bounds buffering latency

    def min_deadline(self) -> float:
        """Earliest absolute deadline among the buffered chunk owners."""
        ds = [r.deadline_t for r, _, _ in self.chunks
              if r.deadline_t is not None]
        return min(ds) if ds else math.inf


class BatchScheduler:
    """Coalesces per-request tile chunks into shared device batches.

    Owns one counting :class:`Dispatcher` and one :class:`ListDispatcher`
    per ``l`` (lazily created, sharing one resolved device list), the
    EDF/LPT pull policy, and the per-``(mode, l, T)`` fuse buffers.
    Driven synchronously by the service's scheduler thread:
    :meth:`admit` new requests, :meth:`step` until False (no pullable
    stream), then :meth:`flush_all` + :meth:`drain` to push everything
    in flight out to the sinks.
    """

    def __init__(
        self,
        *,
        devices=None,
        backend: Optional[str] = None,
        chunk_tiles: int = 64,
        fuse_rows: int = 256,
        flush_slack_s: float = 0.02,
        max_buffer_wait_s: float = 0.01,
        capacity=None,
        max_capacity: Optional[int] = None,
        plan_cache_dir: Optional[str] = None,
        async_staging: bool = True,
        max_inflight: int = 2,
        shed_on_projected_miss: bool = False,
        stats: Optional[ServeStats] = None,
        engine_stats: Optional[Stats] = None,
    ) -> None:
        self.devices = resolve_devices(devices)
        self.backend = backend
        self.chunk_tiles = max(1, int(chunk_tiles))
        self.fuse_rows = max(1, int(fuse_rows))
        self.flush_slack_s = float(flush_slack_s)
        self.max_buffer_wait_s = float(max_buffer_wait_s)
        # a long-lived service defaults to the speculative capacity
        # ratchet: unlike a one-shot query, its per-tile-width guesses
        # converge once and then stay warm across every later request,
        # so steady-state listing costs one device pass per batch instead
        # of sized mode's two -- with identical emitted triples (a short
        # guess is retried on the device at the exact size, never dropped)
        self.capacity = "speculative" if capacity is None else capacity
        self.max_capacity = max_capacity
        self.plan_cache_dir = plan_cache_dir
        self.async_staging = async_staging
        self.max_inflight = max_inflight
        self.shed_on_projected_miss = bool(shed_on_projected_miss)
        self.stats = stats if stats is not None else ServeStats()
        self.engine_stats = engine_stats if engine_stats is not None \
            else Stats()
        self.stats_lock = threading.Lock()
        self._active: List[_ActiveStream] = []
        self._buffers: Dict[Tuple[str, int, int], _FuseBuffer] = {}
        self._cdisps: Dict[int, Dispatcher] = {}
        self._ldisps: Dict[int, ListDispatcher] = {}
        self._arrivals = 0
        # load-shedding throughput estimate: recent (time, tiles) pull
        # samples over a sliding window.  The window (rather than a
        # lifetime tiles/elapsed ratio anchored at the first-ever pull)
        # keeps the rate honest across idle gaps: a service that sat
        # quiet for a minute would otherwise see its apparent throughput
        # decay toward zero and shed the first requests of the next burst
        self._rate_samples: "deque" = deque()
        self._rate_window_s = 30.0

    # -- dispatcher pools ---------------------------------------------------

    def _count_disp(self, l: int) -> Dispatcher:
        disp = self._cdisps.get(l)
        if disp is None:
            disp = Dispatcher(
                l, self.devices, et=True, backend=self.backend,
                async_staging=self.async_staging,
                max_inflight=self.max_inflight, stats=self.engine_stats,
            )
            self._cdisps[l] = disp
        return disp

    def _list_disp(self, l: int) -> ListDispatcher:
        disp = self._ldisps.get(l)
        if disp is None:
            disp = ListDispatcher(
                l, self.devices, sink=None, stats=self.engine_stats,
                capacity=self.capacity, max_capacity=self.max_capacity,
                backend=self.backend, async_staging=self.async_staging,
                max_inflight=self.max_inflight, et_t=ET_T,
            )
            self._ldisps[l] = disp
        return disp

    # -- lifecycle ----------------------------------------------------------

    @property
    def n_active(self) -> int:
        """Requests currently being pulled from (admitted, not exhausted)."""
        return len(self._active)

    def admit(self, req: Request) -> None:
        """Open a request's tile stream off the (cached) plan.

        The plan lookup is the only potentially heavy admission work
        (O(delta*m) on a cold graph); warm graphs hit the keyed plan
        cache and admission is O(selected tiles) index work.

        With ``shed_on_projected_miss`` enabled, a deadline-bearing
        request whose projected completion (backlog / observed tile
        throughput) already exceeds its deadline is rejected here with
        :class:`ServiceOverloaded` instead of admitted-to-miss.
        """
        req.mark_admitted()
        with trace.span("serve/admit", rid=req.rid, k=req.k, mode=req.mode):
            plan = pipeline.cached_plan(
                req.g, req.order, cache_dir=self.plan_cache_dir,
                stats=req.stats)
            table = plan.table(req.order)
            ids = table.select(req.k, use_rule2=req.use_rule2)
        self._maybe_shed(req, int(ids.size))
        req._on_isolated = self._count_isolated
        stream = pipeline.stream_batches(
            plan, req.k, order=req.order, use_rule2=req.use_rule2,
            batch_size=self.chunk_tiles, pack_workers=0, stats=req.stats)
        self._active.append(
            _ActiveStream(req, stream, int(ids.size), self._arrivals))
        self._arrivals += 1

    # -- scheduling ---------------------------------------------------------

    def _observe_tiles(self, n: int, now: Optional[float] = None) -> None:
        """Record ``n`` pulled tiles into the sliding rate window."""
        if now is None:
            now = time.monotonic()
        self._rate_samples.append((now, n))
        horizon = now - self._rate_window_s
        while self._rate_samples and self._rate_samples[0][0] < horizon:
            self._rate_samples.popleft()

    def _observed_rate(self, now: Optional[float] = None
                       ) -> Optional[float]:
        """Recent tile throughput (tiles/s), or None when untrustworthy.

        None -- and therefore permissive admission -- until the window
        holds at least ``fuse_rows`` tiles over a measurable span.  A
        cold service, or one whose last work fell out of the window
        during an idle stretch, admits rather than shedding on a stale
        or nonexistent estimate.
        """
        if now is None:
            now = time.monotonic()
        horizon = now - self._rate_window_s
        while self._rate_samples and self._rate_samples[0][0] < horizon:
            self._rate_samples.popleft()
        if not self._rate_samples:
            return None
        tiles = sum(n for _, n in self._rate_samples)
        if tiles < self.fuse_rows:
            return None
        elapsed = now - self._rate_samples[0][0]
        if elapsed <= 0:
            return None
        return tiles / elapsed

    def _maybe_shed(self, req: Request, new_tiles: int) -> None:
        """Reject a deadline-bearing request projected to miss (knob-gated).

        Uses the scheduler's own cost model: observed tile throughput
        over the sliding window against the backlog (active remaining
        tiles + this request's selected tiles).  Conservative by design:
        permissive until the window holds enough recent pulls to trust
        the rate -- a cold start or post-idle burst is never shed on a
        missing or stale estimate.
        """
        if not self.shed_on_projected_miss or req.deadline_t is None:
            return
        rate = self._observed_rate()
        if rate is None:
            return  # no trustworthy throughput estimate yet
        backlog = sum(a.remaining for a in self._active) + new_tiles
        projected = time.monotonic() + backlog / max(rate, 1e-9)
        if projected > req.deadline_t:
            with self.stats_lock:
                self.stats.shed += 1
                self.stats.rejected += 1
            trace.instant("serve/shed", rid=req.rid,
                          backlog=backlog, rate=round(rate, 1))
            raise ServiceOverloaded(
                f"projected completion {projected - req.deadline_t:.3f}s "
                f"past deadline (backlog {backlog} tiles at "
                f"{rate:.0f} tiles/s): request shed at admission")

    def _isolate(self, a: _ActiveStream, exc: BaseException) -> None:
        """Fail one active request in place; the scheduler keeps running."""
        try:
            a.stream.close()
        except Exception:
            pass
        if a in self._active:
            self._active.remove(a)
        self._note_isolated(a.req, exc)

    def _note_isolated(self, req: Request, exc: BaseException) -> None:
        req.fail(exc)
        self._count_isolated(req, exc)

    def _count_isolated(self, req: Request, exc: BaseException) -> None:
        with self.stats_lock:
            self.stats.isolated_failures += 1
        trace.instant("serve/isolate", rid=req.rid, error=repr(exc))

    def _cancel_expired(self, now: Optional[float] = None) -> None:
        """Cooperatively cancel deadline-enforced requests past expiry.

        The stream is closed (no further pulls), the request leaves the
        active set, and its ticket resolves with
        :class:`~repro.serve.request.DeadlineExceeded` carrying partial
        results.  In-flight fused chunks it still owns are dropped by the
        sequencer's resolved-request guard.
        """
        if now is None:
            now = time.monotonic()
        for a in list(self._active):
            req = a.req
            if not req.enforce_deadline or req.deadline_t is None:
                continue
            if now < req.deadline_t:
                continue
            try:
                a.stream.close()
            except Exception:
                pass
            self._active.remove(a)
            if req.cancel_deadline(now):
                with self.stats_lock:
                    self.stats.deadline_cancels += 1
                trace.instant("serve/deadline_cancel", rid=req.rid)

    def _finish_stream(self, a: _ActiveStream) -> None:
        a.stream.close()
        self._active.remove(a)
        a.req.finish_feeding()

    def _pick(self) -> Optional[_ActiveStream]:
        # listing early stop: a full sink retires its request's stream
        for a in list(self._active):
            if a.req.full:
                self._finish_stream(a)
        if not self._active:
            return None
        i = edf_pick([(a.req.deadline_t, a.remaining, a.idx)
                      for a in self._active])
        return self._active[i]

    def step(self, now: Optional[float] = None) -> bool:
        """Pull one chunk from the EDF/LPT pick; True if progress was made.

        Oversize spill tiles are computed inline on the host and
        delivered immediately (through the owner's sequencer, so order
        holds); packed chunks accumulate in fuse buffers, flushed at
        ``fuse_rows`` or under deadline pressure.

        Failure containment: an exception out of one request's tile
        stream or spill compute isolates *that* request (its ticket
        resolves exceptionally) and scheduling continues -- one bad
        request never takes down its cotenants.
        """
        self._cancel_expired(now)
        self._flush_expiring(now)
        a = self._pick()
        if a is None:
            return False
        req = a.req
        try:
            item = next(a.stream)
        except StopIteration:
            self._finish_stream(a)
            return True
        except Exception as exc:  # per-request containment (stream died)
            self._isolate(a, exc)
            return True
        seq = req.next_seq()
        if isinstance(item, tiles_mod.Tile):
            a.remaining -= 1
            self._observe_tiles(1)
            with self.stats_lock:
                self.stats.spill_tiles += 1
            t0 = time.monotonic()
            try:
                if req.mode == "count":
                    with trace.span("spill/count", s=item.s, rid=req.rid):
                        payload = engine_jax.count_spilled(
                            item, req.order, req.l, req.stats, ET_T,
                            req.use_rule2)
                else:
                    payload = listing.list_spilled(
                        item, req.l, req.stats, et_t=ET_T)
            except Exception as exc:  # containment (host spill died)
                self._isolate(a, exc)
                return True
            req.add_stage("device", time.monotonic() - t0)
            req.deliver(seq, payload)
            return True
        a.remaining -= item.B
        self._observe_tiles(item.B)
        key = (req.mode, req.l, item.T)
        buf = self._buffers.get(key)
        if buf is None:
            buf = self._buffers[key] = _FuseBuffer(time.monotonic())
        buf.chunks.append((req, seq, item))
        buf.pull_ts.append(time.monotonic())
        buf.rows += item.B
        if buf.rows >= self.fuse_rows:
            self._flush(key)
        return True

    def _flush_expiring(self, now: Optional[float] = None) -> None:
        """Flush buffers under deadline pressure or past the age bound.

        A buffer flushes early when the earliest owner deadline is within
        ``flush_slack_s``, or when its first chunk has waited
        ``max_buffer_wait_s`` -- the bound on fusion-induced latency when
        no same-key chunk shows up to complete the batch.
        """
        if now is None:
            now = time.monotonic()
        for key in list(self._buffers):
            buf = self._buffers[key]
            if now + self.flush_slack_s >= buf.min_deadline():
                with self.stats_lock:
                    self.stats.deadline_flushes += 1
                self._flush(key)
            elif now - buf.created_t >= self.max_buffer_wait_s:
                self._flush(key)

    def _flush(self, key: Tuple[str, int, int]) -> None:
        buf = self._buffers.pop(key, None)
        if buf is None or not buf.chunks:
            return
        mode, l, _T = key
        flush_t = time.monotonic()
        for (req, _seq, _b), t_pull in zip(buf.chunks, buf.pull_ts):
            req.add_stage("fuse", flush_t - t_pull)
        fused, segments = fuse_chunks(buf.chunks)
        n_owners = len({id(r) for r, _, _, _, _ in segments})
        with self.stats_lock:
            self.stats.fused_batches += 1
            self.stats.fused_rows += fused.B
            self.stats.fused_chunks += len(segments)
            if n_owners > 1:
                self.stats.cross_request_batches += 1
        trace.instant(
            "serve/fuse_flush", mode=mode, l=l, T=fused.T,
            rows=fused.B, chunks=len(segments), owners=n_owners,
        )
        if mode == "count":

            def route(hard, nv, t, f, segments=segments, l=l,
                      flush_t=flush_t):
                dt = time.monotonic() - flush_t
                for req, seq, s0, s1, _ in segments:
                    # per-segment containment: one request's combine /
                    # delivery failure never poisons its batchmates
                    try:
                        payload = engine_jax.combine_counts(
                            hard[s0:s1], nv[s0:s1], t[s0:s1], f[s0:s1],
                            l, True)
                        req.add_stage("device", dt)
                        trace.async_instant(
                            "request/device", id=req.rid, seq=seq,
                            rows=s1 - s0)
                        req.deliver(seq, payload)
                    except Exception as exc:
                        self._note_isolated(req, exc)

            disp, token = self._count_disp(l), "count"
        else:

            def route(_batch, bufs, cnt, ovf, segments=segments, l=l,
                      flush_t=flush_t):
                dt = time.monotonic() - flush_t
                total = 0
                for req, seq, s0, s1, chunk in segments:
                    # per-segment containment (see the count route)
                    try:
                        rows = listing.decode_batch(
                            chunk, bufs[s0:s1], cnt[s0:s1], ovf[s0:s1], l,
                            req.stats, et_t=ET_T)
                        req.add_stage("device", dt)
                        trace.async_instant(
                            "request/device", id=req.rid, seq=seq,
                            rows=rows.shape[0])
                        req.deliver(seq, rows)
                        total += rows.shape[0]
                    except Exception as exc:
                        self._note_isolated(req, exc)
                return total

            disp, token = self._list_disp(l), "list"
        try:
            disp.submit(fused, route=route)
        except Exception as exc:
            # the dispatcher itself rejected the batch (past its own
            # retry/demotion ladder): fail the owners, keep the service up
            trace.instant("serve/submit_failed", mode=token, error=repr(exc))
            for req in {id(r): r for r, _, _, _, _ in segments}.values():
                self._note_isolated(req, exc)

    def flush_all(self) -> None:
        """Flush every fuse buffer (stream exhaustion / idle / shutdown)."""
        for key in list(self._buffers):
            self._flush(key)

    def drain(self) -> None:
        """Block until all in-flight device work has routed to requests."""
        for disp in self._cdisps.values():
            disp.drain()
        for disp in self._ldisps.values():
            disp.drain()

    def finish(self) -> None:
        """Tear the dispatchers down (decode workers, compile accounting)."""
        self.flush_all()
        for disp in self._cdisps.values():
            disp.finish()
        for disp in self._ldisps.values():
            disp.finish()

    def fail_active(self, exc: BaseException) -> None:
        """Resolve every active request exceptionally (scheduler error)."""
        for a in list(self._active):
            try:
                a.stream.close()
            except Exception:
                pass
            a.req.fail(exc)
        self._active.clear()
        self._buffers.clear()
