"""CliqueService: the long-lived multi-tenant serving front door.

DESIGN.md section 10.  One service owns a graph registry, a bounded
:class:`~repro.serve.request.RequestQueue`, a
:class:`~repro.serve.scheduler.BatchScheduler`, and a single scheduler
thread that drives admission -> pull -> coalesce -> dispatch.  Client
threads call :meth:`CliqueService.submit` and block on the returned
:class:`~repro.serve.request.Ticket`; everything device-side is shared:
plans via the keyed plan cache, executables via the process-wide jit
caches and pow2 batch bucketing, dispatchers across all requests.

Request lifecycle::

    submit() -> RequestQueue -> admit (plan lookup, open tile stream)
      -> EDF/LPT chunk pulls -> fuse buffers -> shared Dispatcher /
      ListDispatcher -> route callbacks -> per-request sequencer ->
      sink -> Ticket.result()

Overload behavior: a full queue rejects non-blocking submits with
:class:`~repro.serve.request.ServiceOverloaded` (counted in
``ServeStats.rejected``); with ``shed_on_projected_miss=True`` the
scheduler additionally sheds deadline-bearing requests whose projected
completion already misses (``ServeStats.shed``).  Deadlines are
accounting only by default -- admitted work completes exactly, late or
not -- unless a request opts into ``enforce_deadline=True``, in which
case expiry cooperatively cancels that request (and only it) with
:class:`~repro.serve.request.DeadlineExceeded`.

Failure containment (DESIGN.md section 12): one request's engine,
sink, or stream exception resolves *that* ticket exceptionally while
the scheduler thread and every cotenant request keep running.
"""

from __future__ import annotations

import threading
import time
from typing import Optional, Union

import numpy as np

from ..core.engine_np import Stats
from ..core.graph import Graph
from ..delta import PlanIndex
from ..obs import metrics as obs_metrics
from ..obs import trace
from ..obs.export import MetricsServer
from .request import (Request, RequestQueue, ServiceClosed, Ticket)
from .scheduler import BatchScheduler, ServeStats

#: rows per delivered chunk when streaming a delta subscription read
#: through the sequencer (keeps individual sink emits bounded)
_DELTA_CHUNK_ROWS = 4096


class _GraphEntry:
    """One registered graph: current snapshot, version, delta lineage.

    ``index`` (a :class:`~repro.delta.PlanIndex`) is created lazily on
    the first :meth:`CliqueService.update_graph` call -- a never-mutated
    graph pays nothing for the dynamic-graph machinery.  ``lock``
    serializes updates and delta reads per entry (PlanIndex is not
    thread-safe by itself).
    """

    __slots__ = ("graph", "index", "lock")

    def __init__(self, g: Graph) -> None:
        self.graph = g
        self.index: Optional[PlanIndex] = None
        self.lock = threading.Lock()

    @property
    def version(self) -> int:
        return 0 if self.index is None else self.index.version


class CliqueService:
    """Continuous-batching k-clique serving tier over the JAX engines.

    Typical use::

        svc = CliqueService(devices="all", plan_cache_dir="/tmp/plans")
        svc.register_graph("social", g)
        t1 = svc.submit("social", k=5, mode="count")
        t2 = svc.submit("social", k=5, mode="list", max_out=100,
                        deadline_s=0.2)
        print(t1.result().count, t2.result().rows)
        svc.close()

    Construction knobs: ``devices`` / ``backend`` / ``async_staging`` /
    ``max_inflight`` mirror the single-query engines; ``chunk_tiles`` is
    the per-request pull granularity (smaller = finer interleaving,
    more fusion), ``fuse_rows`` the target fused-batch rows (matches the
    single-query default batch size so fused batches reuse the same warm
    executables), ``flush_slack_s`` how close to a deadline a partial
    buffer is flushed early, ``max_buffer_wait_s`` the age bound on a
    partial fuse buffer (caps fusion-induced latency when no mergeable
    chunk shows up), ``max_pending`` the admission-queue bound
    (backpressure), and ``max_active`` how many requests are pulled from
    concurrently.

    Thread safety: ``submit`` / ``register_graph`` / ``stats`` are safe
    from any thread; one internal scheduler thread does all engine work.
    Results are exact and per-request byte-identical to serial execution
    (see DESIGN.md section 10 for the invariant and its mechanism).
    """

    def __init__(
        self,
        *,
        devices=None,
        backend: Optional[str] = None,
        max_pending: int = 256,
        max_active: int = 16,
        chunk_tiles: int = 64,
        fuse_rows: int = 256,
        flush_slack_s: float = 0.02,
        max_buffer_wait_s: float = 0.01,
        capacity=None,
        max_capacity: Optional[int] = None,
        plan_cache_dir: Optional[str] = None,
        async_staging: bool = True,
        max_inflight: int = 2,
        shed_on_projected_miss: bool = False,
        metrics_port: Optional[int] = None,
        start: bool = True,
    ) -> None:
        self.stats = ServeStats()
        self.engine_stats = Stats()
        # service-level rollup of completed requests' per-request Stats
        # (folded in via Stats.merge at completion; the dispatcher-shared
        # engine_stats tracks device-side work, this tracks request-side)
        self.request_stats = Stats()
        self._sched = BatchScheduler(
            devices=devices,
            backend=backend,
            chunk_tiles=chunk_tiles,
            fuse_rows=fuse_rows,
            flush_slack_s=flush_slack_s,
            max_buffer_wait_s=max_buffer_wait_s,
            capacity=capacity,
            max_capacity=max_capacity,
            plan_cache_dir=plan_cache_dir,
            async_staging=async_staging,
            max_inflight=max_inflight,
            shed_on_projected_miss=shed_on_projected_miss,
            stats=self.stats,
            engine_stats=self.engine_stats,
        )
        self.max_active = max(1, int(max_active))
        self._queue = RequestQueue(max_pending)
        self._graphs: dict = {}
        self._graphs_lock = threading.Lock()
        self._resume = threading.Event()
        self._resume.set()
        self._closing = threading.Event()
        self._abort = threading.Event()  # close(drain=False): shed, don't finish
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        # /metrics exposition (off by default; metrics_port=0 = ephemeral)
        self._metrics_server: Optional[MetricsServer] = None
        self._registry = obs_metrics.get_registry()
        if metrics_port is not None:
            self._registry.add_collector(self._collect_metrics)
            self._metrics_server = MetricsServer(
                port=metrics_port, registry=self._registry)
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Start the scheduler thread (idempotent)."""
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._run, name="clique-serve", daemon=True)
        self._thread.start()

    def pause(self) -> None:
        """Halt admission+scheduling; queued submits accumulate.

        A test/ops hook: pause, submit a burst, :meth:`resume` -- the
        whole burst is then admitted together, maximizing cross-request
        fusion determinism in tests.
        """
        self._resume.clear()

    def resume(self) -> None:
        """Resume the scheduler after :meth:`pause`."""
        self._resume.set()

    def close(self, timeout: Optional[float] = None,
              drain: bool = True) -> None:
        """Drain queued+active requests, then shut the tier down.

        Blocks until the scheduler thread exits (up to ``timeout``) and
        the dispatchers are finished.  Idempotent.  With ``drain=False``
        in-flight and queued requests are not completed: every
        unresolved ticket resolves with
        :class:`~repro.serve.request.ServiceClosed` (no hang) and the
        tier shuts down as fast as device teardown allows.
        """
        if not drain:
            self._abort.set()
        self._closing.set()
        self._resume.set()
        self._queue.close()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._sched.finish()
        if self._metrics_server is not None:
            self._registry.remove_collector(self._collect_metrics)
            self._metrics_server.close()
            self._metrics_server = None

    def __enter__(self) -> "CliqueService":
        """Context-manager entry: the started service itself."""
        self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Context-manager exit: full drain + shutdown."""
        self.close()

    # -- client API ---------------------------------------------------------

    @property
    def metrics_address(self) -> Optional[str]:
        """``host:port`` of the /metrics endpoint, or None when disabled."""
        if self._metrics_server is None:
            return None
        return self._metrics_server.address

    def register_graph(self, name: str, g: Graph) -> None:
        """Register ``g`` under ``name`` for by-name submission.

        Safe from any thread.  Re-registering a name replaces the graph
        (at version 0, with no delta lineage) for *future* submissions
        only.
        """
        with self._graphs_lock:
            self._graphs[name] = _GraphEntry(g)

    def graph_version(self, name: str) -> int:
        """Current version of a registered graph (0 until first update)."""
        return self._entry(name).version

    def update_graph(self, name: str, insert=None, delete=None,
                     *, order: str = "hybrid") -> int:
        """Apply one edge batch to a registered graph; returns the version.

        Runs :meth:`~repro.delta.PlanIndex.apply_batch`: the mutated
        graph's plan is locally repaired (or rebuilt past the churn
        threshold) and published into the keyed plan cache, so the next
        submission against ``name`` admits against a warm plan --
        post-mutation queries pay O(touched neighborhood), not
        O(delta*m).  The new snapshot is swapped in atomically under the
        scheduler's stats lock; in-flight requests keep streaming their
        admitted snapshot (exactly the re-registration semantics).

        ``order`` fixes the maintained plan family on the *first* update
        of this graph; later updates reuse the entry's index.  Safe from
        any thread; updates to one graph serialize, different graphs
        proceed concurrently.
        """
        entry = self._entry(name)
        with entry.lock:
            if entry.index is None:
                entry.index = PlanIndex(
                    entry.graph, order,
                    cache_dir=self._sched.plan_cache_dir,
                    stats=self.engine_stats)
            version = entry.index.apply_batch(insert=insert, delete=delete)
            with self._sched.stats_lock:
                entry.graph = entry.index.graph
                self.stats.graph_updates += 1
        trace.instant("serve/graph_update", graph=name, version=version)
        return version

    def _entry(self, name: str) -> _GraphEntry:
        with self._graphs_lock:
            entry = self._graphs.get(name)
        if entry is None:
            raise KeyError(f"unknown graph {name!r}; register_graph first")
        return entry

    def submit(
        self,
        graph: Union[str, Graph],
        k: int,
        mode: str = "count",
        *,
        order: str = "hybrid",
        use_rule2: bool = True,
        vertex_filter: Optional[int] = None,
        max_out: Optional[int] = None,
        deadline_s: Optional[float] = None,
        enforce_deadline: bool = False,
        sink=None,
        since_version: Optional[int] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> Ticket:
        """Submit one query; returns immediately with a :class:`Ticket`.

        ``graph`` is a registered name or a ``Graph`` instance.  ``mode``
        is ``"count"``, ``"list"``, or ``"delta"``; listing honors
        ``vertex_filter`` (keep cliques containing that vertex),
        ``max_out`` (truncate after filtering, with early stop), and a
        custom ``sink``.  ``mode="delta"`` is the subscription read --
        rows of k-cliques *gained* since ``since_version`` of a
        registered (by-name only) graph, answered from the delta lineage
        maintained by :meth:`update_graph` and streamed through the same
        sequencer/sink path as listing (so ``vertex_filter`` /
        ``max_out`` / ``sink`` compose); ``since_version`` equal to the
        current version yields an empty result, one ahead of it or
        behind the retained history resolves the ticket with
        ``ValueError``.  ``deadline_s`` is a relative latency target used
        for EDF
        scheduling and miss accounting; with ``enforce_deadline=True``
        it becomes real: at expiry the scheduler cancels this request
        cooperatively and the ticket raises
        :class:`~repro.serve.request.DeadlineExceeded` carrying any
        partial results.

        Backpressure: with ``block=False`` a full admission queue raises
        :class:`~repro.serve.request.ServiceOverloaded` instead of
        waiting (``timeout`` bounds the blocking wait).  Raises
        :class:`~repro.serve.request.ServiceClosed` after :meth:`close`.

        Thread-safe; callable from any number of client threads.
        """
        if self._closing.is_set():
            raise ServiceClosed("service is closed")
        entry = None
        if isinstance(graph, str):
            entry = self._entry(graph)
            g = entry.graph
        else:
            if mode == "delta":
                raise ValueError(
                    "delta mode requires a registered graph name (the "
                    "version lineage lives in the registry)")
            g = graph
        req = Request(
            g, k, mode, order=order, use_rule2=use_rule2,
            vertex_filter=vertex_filter, max_out=max_out,
            deadline_s=deadline_s, enforce_deadline=enforce_deadline,
            sink=sink, since_version=since_version,
        )
        req._delta_entry = entry
        req._on_done = self._record_done
        req.mark_submitted()
        if mode == "count" and k < 3:
            # closed forms; answered at admission, never scheduled
            with self._sched.stats_lock:
                self.stats.admitted += 1
            req.deliver(req.next_seq(), g.n if k == 1 else g.m)
            req.finish_feeding()
            return Ticket(req)
        try:
            self._queue.put(req, block=block, timeout=timeout)
        except Exception:
            with self._sched.stats_lock:
                self.stats.rejected += 1
            trace.async_end("request", id=req.rid, rejected=True)
            raise
        with self._sched.stats_lock:
            self.stats.admitted += 1
        return Ticket(req)

    # -- internals ----------------------------------------------------------

    def _record_done(self, result) -> None:
        with self._sched.stats_lock:
            self.stats.completed += 1
            if result.deadline_missed:
                self.stats.deadline_missed += 1
            if result.stats is not None:
                self.request_stats.merge(result.stats)
        self._registry.histogram(
            "repro_request_latency_seconds",
            help="end-to-end request latency (submit to resolve)",
        ).observe(result.latency_s)
        for stage, dt in (result.stage_s or {}).items():
            self._registry.counter(
                "repro_request_stage_seconds_total",
                help="wall seconds per request lifecycle stage",
                stage=stage,
            ).inc(dt)

    def _collect_metrics(self) -> None:
        # scrape-time publication of the lifetime accumulators; counters
        # only move forward (set_total keeps the max) so this is safe to
        # call concurrently with the scheduler thread mutating the stats
        with self._sched.stats_lock:
            obs_metrics.publish_totals(
                self.stats, "repro_serve", self._registry)
            obs_metrics.publish_totals(
                self.engine_stats, "repro_engine", self._registry)
            obs_metrics.publish_totals(
                self.request_stats, "repro_request", self._registry)
        self._registry.gauge(
            "repro_serve_queue_depth",
            help="requests waiting for admission",
        ).set(len(self._queue))
        self._registry.gauge(
            "repro_serve_active_requests",
            help="requests currently being pulled from",
        ).set(self._sched.n_active)

    def _admit_safe(self, req: Request) -> None:
        try:
            if req.mode == "delta":
                self._serve_delta(req)
            else:
                self._sched.admit(req)
        except Exception as exc:  # bad request: resolve it, keep serving
            req.fail(exc)

    def _serve_delta(self, req: Request) -> None:
        """Answer a subscription read from the graph's delta lineage.

        Runs on the scheduler thread at admission (delta reads are
        in-memory set algebra over retained per-batch deltas -- no tile
        stream to schedule).  Rows are delivered in bounded chunks
        through the request's sequencer, so vertex filtering, max_out
        truncation, custom sinks, and failure isolation all behave
        exactly as in listing mode.
        """
        req.mark_admitted()
        entry = req._delta_entry
        with self._sched.stats_lock:
            self.stats.delta_requests += 1
        with trace.span("serve/delta", rid=req.rid, k=req.k,
                        since=req.since_version):
            with entry.lock:
                if entry.index is None:
                    if req.since_version != 0:
                        raise ValueError(
                            f"since={req.since_version} outside [0, 0]")
                    rows = np.zeros((0, req.k), dtype=np.int64)
                else:
                    rows = entry.index.delta(req.k, req.since_version).gained
        for start in range(0, rows.shape[0], _DELTA_CHUNK_ROWS):
            if req.full:
                break
            req.deliver(req.next_seq(),
                        rows[start:start + _DELTA_CHUNK_ROWS])
        req.finish_feeding()

    def _shed_all(self, exc: BaseException) -> None:
        """Resolve every active and queued request with ``exc``."""
        self._sched.fail_active(exc)
        while True:
            req = self._queue.get_nowait()
            if req is None:
                break
            req.fail(exc)

    def _run(self) -> None:
        sched, queue = self._sched, self._queue
        try:
            while True:
                if self._abort.is_set():
                    # close(drain=False): resolve everything, skip the work
                    self._shed_all(ServiceClosed(
                        "service closed (drain=False)"))
                    break
                if not self._resume.is_set():
                    if self._closing.is_set():
                        self._resume.set()
                        continue
                    self._resume.wait(0.05)
                    continue
                while sched.n_active < self.max_active:
                    req = queue.get_nowait()
                    if req is None:
                        break
                    self._admit_safe(req)
                if sched.step():
                    continue
                # no pullable stream: push pending + in-flight work out so
                # every delivered request resolves before we block
                sched.flush_all()
                sched.drain()
                if self._closing.is_set() and len(queue) == 0 \
                        and sched.n_active == 0:
                    break
                req = queue.get(timeout=0.05)
                if req is not None:
                    self._admit_safe(req)
        except (KeyboardInterrupt, SystemExit):  # never swallow these
            raise
        except Exception as exc:
            # the scheduler *infrastructure* died (per-request failures
            # are contained upstream and never reach here): fail every
            # waiter with the real error so no ticket hangs, then re-raise
            self._error = exc
            self._shed_all(exc)
            raise
