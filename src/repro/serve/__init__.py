"""Multi-tenant continuous-batching serving tier (DESIGN.md section 10).

Admits concurrent ``(graph, k, mode, vertex-filter, max_out, deadline)``
requests, coalesces ready tiles from different requests into shared
fixed-shape device batches, and routes exact counts / byte-identical
clique rows back to per-request sinks under EDF/LPT scheduling.
"""

from .request import (
    ET_T,
    DeadlineExceeded,
    Request,
    RequestQueue,
    RequestResult,
    ServiceClosed,
    ServiceOverloaded,
    Ticket,
    apply_vertex_filter,
)
from .scheduler import BatchScheduler, ServeStats, edf_pick, fuse_chunks
from .service import CliqueService

__all__ = [
    "ET_T",
    "BatchScheduler",
    "CliqueService",
    "DeadlineExceeded",
    "Request",
    "RequestQueue",
    "RequestResult",
    "ServeStats",
    "ServiceClosed",
    "ServiceOverloaded",
    "Ticket",
    "apply_vertex_filter",
    "edf_pick",
    "fuse_chunks",
]
