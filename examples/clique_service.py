"""End-to-end driver (the paper's kind is a graph-mining operator, so the
end-to-end application is a distributed clique-analytics service):

  1. ingest a stream of graph snapshots (synthetic RMAT / power-law);
  2. preprocess on host: truss decomposition -> pi_tau -> tau-bounded tiles;
  3. schedule tiles across devices with LPT cost balancing (EP scheme);
  4. count k-cliques on the accelerator engine (Pallas kernels);
  5. serve per-snapshot clique-density reports, with checkpointed progress
     so a killed service resumes at the next snapshot.

    PYTHONPATH=src python examples/clique_service.py --snapshots 3 --k 5
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import engine_jax
from repro.core.truss import truss_decomposition
from repro.data import powerlaw_graph, rmat_graph
from repro.runtime.clique_scheduler import schedule_tiles


def snapshot(i: int):
    if i % 2 == 0:
        return f"rmat-{i}", rmat_graph(11, 6, seed=100 + i)
    return f"powerlaw-{i}", powerlaw_graph(2500, 10, seed=100 + i)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=3)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--ckpt", default="/tmp/repro_clique_service")
    args = ap.parse_args()

    start = 0
    got = restore_checkpoint(args.ckpt, {"done": jnp.zeros((), jnp.int32)})
    if got:
        start = int(got["tree"]["done"])
        print(f"resuming after snapshot {start - 1}")

    l = args.k - 2
    for i in range(start, args.snapshots):
        name, g = snapshot(i)
        t0 = time.time()
        td = truss_decomposition(g)
        binned = engine_jax.bin_tiles(g, args.k)
        total = 0
        n_tiles = 0
        for T, packed in binned.items():
            metas = [type("M", (), {"s": T, "nedges": 2 * T})()
                     for _ in range(packed.A.shape[0])]
            _, stats = schedule_tiles(metas, l, jax.device_count())
            hard, nv, t, f = engine_jax.count_packed(
                jnp.asarray(packed.A), jnp.asarray(packed.cand), l,
                et=True, interpret=True)
            total += engine_jax.combine_counts(hard, nv, t, f, l, True)
            n_tiles += packed.A.shape[0]
        dt = time.time() - t0
        density = total / max(g.n, 1)
        print(f"[{name}] n={g.n} m={g.m} tau={td.tau} -> "
              f"{total} {args.k}-cliques ({density:.2f}/vertex) "
              f"tiles={n_tiles} in {dt:.2f}s")
        save_checkpoint(args.ckpt, i + 1,
                        {"done": jnp.int32(i + 1)},
                        metadata={"snapshot": name, "count": int(total)})
    print("service drained; progress checkpointed at", args.ckpt)


if __name__ == "__main__":
    main()
