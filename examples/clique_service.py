"""End-to-end driver (the paper's kind is a graph-mining operator, so the
end-to-end application is a distributed clique-analytics service):

  1. ingest a stream of graph snapshots (synthetic RMAT / power-law);
  2. preprocess on host ONCE per snapshot: truss decomposition -> pi_tau ->
     k-independent tile membership table (repro.core.pipeline.PipelinePlan);
  3. answer several k-clique queries per snapshot off the same plan --
     repeated queries skip preprocessing entirely (the serving win);
  4. stream capacity-batched packed tiles and shard them across ALL local
     devices (repro.runtime.dispatch: scheduler LPT bins -> real devices,
     double-buffered host->device staging), exact host combine;
  5. serve per-snapshot clique-density reports AND a materializing query --
     "top-N k-cliques containing vertex v" -- off the SAME cached plan via
     the emission subsystem (repro.core.listing), with checkpointed
     progress so a killed service resumes at the next snapshot.

    PYTHONPATH=src python examples/clique_service.py --snapshots 3 --k 5
    # multi-device serving on a CPU host:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/clique_service.py --snapshots 3
"""
import argparse
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import engine_jax, listing, pipeline
from repro.data import powerlaw_graph, rmat_graph


def snapshot(i: int):
    if i % 2 == 0:
        return f"rmat-{i}", rmat_graph(11, 6, seed=100 + i)
    return f"powerlaw-{i}", powerlaw_graph(2500, 10, seed=100 + i)


def answer_query(plan, k, devices="all", backend=None):
    """One k-clique query off a prebuilt plan, dispatched across all local
    devices; returns (count, n_tiles, n_spilled, staging overlap s).

    ``backend`` picks the kernel implementation (repro.kernels.ops
    registry; default auto = compiled lax on this CPU host)."""
    r = engine_jax.count(plan.g, k, plan=plan, devices=devices,
                         backend=backend)
    return r.count, r.tiles, r.stats.spilled_tiles, \
        r.stats.staging_overlap_s


class TopNContainingSink(listing.CliqueSink):
    """Keep the first N cliques that contain vertex v (stream order);
    ``full`` stops the producer as soon as N are collected."""

    def __init__(self, v: int, n: int, k: int):
        super().__init__()
        self.v, self.n = v, n
        self._hits = listing.ArraySink(k, max_out=n)

    @property
    def full(self):
        return self._hits.full

    def emit(self, cliques):
        self._hits.emit(cliques[(cliques == self.v).any(axis=1)])
        return self._account(cliques)

    def result(self):
        return self._hits.result()


def answer_topn_query(plan, k, v, topn, devices="all", backend=None):
    """Top-N k-cliques containing vertex v, materialized off the cached
    plan through the emission subsystem; returns ((n, k) rows, stats)."""
    sink = TopNContainingSink(v, topn, k)
    res = listing.stream_cliques(plan, k, sink, devices=devices,
                                 backend=backend)
    return sink.result(), res.stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=3)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--topn", type=int, default=5,
                    help="N for the top-N cliques-containing-v query")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "lax", "ref", "autotune"],
                    help="kernel backend for all queries (default auto = "
                         "compiled lax on CPU hosts)")
    ap.add_argument("--ckpt", default="/tmp/repro_clique_service")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="on-disk plan store: a restarted service reloads "
                         "each snapshot's truss order + tile tables "
                         "instead of re-decomposing (keyed by graph "
                         "content, see pipeline.cached_plan)")
    ap.add_argument("--tune-cache", default=None, metavar="DIR",
                    help="persistent autotuner directory (repro.tune): "
                         "a restarted service reuses tuned backend/geometry "
                         "records and XLA's persistent compilation cache "
                         "instead of re-measuring and re-compiling")
    args = ap.parse_args()
    if args.tune_cache:
        from repro import tune

        tune.configure(args.tune_cache)

    start = 0
    got = restore_checkpoint(args.ckpt, {"done": jnp.zeros((), jnp.int32)})
    if got:
        start = int(got["tree"]["done"])
        print(f"resuming after snapshot {start - 1}")

    for i in range(start, args.snapshots):
        name, g = snapshot(i)
        t0 = time.time()
        # keyed plan cache: in-process hits are free, and with
        # --plan-cache a restarted service skips the decomposition too
        plan_stats = engine_jax.Stats()
        plan = pipeline.cached_plan(g, order="hybrid",
                                    cache_dir=args.plan_cache,
                                    stats=plan_stats)
        t_plan = time.time() - t0
        report = {}
        for k in (args.k, args.k + 1):      # two queries, one plan
            t0 = time.time()
            total, n_tiles, n_spill, overlap = answer_query(
                plan, k, backend=args.backend)
            report[k] = (total, n_tiles, n_spill, overlap, time.time() - t0)
        tau = plan.td.tau
        line = " ".join(
            f"k={k}:{c} ({c / max(g.n, 1):.2f}/vertex, {dt:.2f}s, "
            f"overlap {ov:.2f}s)"
            for k, (c, _, _, ov, dt) in report.items())
        n_tiles = report[args.k][1]
        plan_src = "warm" if plan_stats.plan_cache_hit else "cold"
        print(f"[{name}] n={g.n} m={g.m} tau={tau} tiles={n_tiles} "
              f"devices={jax.device_count()} plan={t_plan:.2f}s "
              f"({plan_src}) -> {line}")
        # materializing query off the SAME plan: top-N cliques @ vertex v
        v = int(np.argmax(g.degrees()))
        t0 = time.time()
        rows, lst = answer_topn_query(plan, args.k, v, args.topn,
                                      backend=args.backend)
        print(f"[{name}] top-{args.topn} {args.k}-cliques @ v={v}: "
              f"{len(rows)} found ({lst.emitted_cliques} scanned, "
              f"overflowed={lst.overflowed_tiles}, {time.time() - t0:.2f}s)"
              + (f" first={rows[0].tolist()}" if len(rows) else ""))
        save_checkpoint(args.ckpt, i + 1,
                        {"done": jnp.int32(i + 1)},
                        metadata={"snapshot": name,
                                  "count": int(report[args.k][0])})
    print("service drained; progress checkpointed at", args.ckpt)


if __name__ == "__main__":
    main()
