"""End-to-end driver (the paper's kind is a graph-mining operator, so the
end-to-end application is a distributed clique-analytics service):

  1. ingest a stream of graph snapshots (synthetic RMAT / power-law);
  2. preprocess on host ONCE per snapshot: truss decomposition -> pi_tau ->
     k-independent tile membership table (repro.core.pipeline.PipelinePlan);
  3. answer several k-clique queries per snapshot off the same plan --
     repeated queries skip preprocessing entirely (the serving win);
  4. stream capacity-batched packed tiles, LPT cost-balance the batches
     across devices (EP scheme), count on the accelerator engine;
  5. serve per-snapshot clique-density reports, with checkpointed progress
     so a killed service resumes at the next snapshot.

    PYTHONPATH=src python examples/clique_service.py --snapshots 3 --k 5
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import engine_jax, pipeline
from repro.data import powerlaw_graph, rmat_graph
from repro.runtime.clique_scheduler import schedule_batches


def snapshot(i: int):
    if i % 2 == 0:
        return f"rmat-{i}", rmat_graph(11, 6, seed=100 + i)
    return f"powerlaw-{i}", powerlaw_graph(2500, 10, seed=100 + i)


def answer_query(plan, k):
    """One k-clique query off a prebuilt plan; returns (count, n_tiles,
    n_spilled, batch balance)."""
    l = k - 2
    batches, spilled = [], []
    for item in pipeline.stream_batches(plan, k):
        (batches if isinstance(item, pipeline.TileBatch)
         else spilled).append(item)
    device_bins, sched = schedule_batches(batches, l, jax.device_count())
    total = 0
    stats = engine_jax.Stats()
    for bin_ids in device_bins:
        for bi in bin_ids:
            b = batches[bi]
            hard, nv, t, f = engine_jax.count_packed(
                jnp.asarray(b.A), jnp.asarray(b.cand), l,
                et=True, interpret=True)
            total += engine_jax.combine_counts(hard, nv, t, f, l, True)
    for tile in spilled:
        total += engine_jax.count_spilled(tile, "hybrid", l, stats,
                                          et_t=3, use_rule2=True)
    n_tiles = sum(b.B for b in batches) + len(spilled)
    return total, n_tiles, len(spilled), sched["max_over_mean"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=3)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--ckpt", default="/tmp/repro_clique_service")
    args = ap.parse_args()

    start = 0
    got = restore_checkpoint(args.ckpt, {"done": jnp.zeros((), jnp.int32)})
    if got:
        start = int(got["tree"]["done"])
        print(f"resuming after snapshot {start - 1}")

    for i in range(start, args.snapshots):
        name, g = snapshot(i)
        t0 = time.time()
        plan = pipeline.build_plan(g, order="hybrid")
        t_plan = time.time() - t0
        report = {}
        for k in (args.k, args.k + 1):      # two queries, one plan
            t0 = time.time()
            total, n_tiles, n_spill, bal = answer_query(plan, k)
            report[k] = (total, n_tiles, n_spill, bal, time.time() - t0)
        tau = plan.td.tau
        line = " ".join(
            f"k={k}:{c} ({c / max(g.n, 1):.2f}/vertex, {dt:.2f}s)"
            for k, (c, _, _, _, dt) in report.items())
        n_tiles = report[args.k][1]
        print(f"[{name}] n={g.n} m={g.m} tau={tau} tiles={n_tiles} "
              f"plan={t_plan:.2f}s -> {line}")
        save_checkpoint(args.ckpt, i + 1,
                        {"done": jnp.int32(i + 1)},
                        metadata={"snapshot": name,
                                  "count": int(report[args.k][0])})
    print("service drained; progress checkpointed at", args.ckpt)


if __name__ == "__main__":
    main()
