"""End-to-end driver (the paper's kind is a graph-mining operator, so the
end-to-end application is a multi-tenant clique-analytics service):

  1. ingest a stream of graph snapshots (synthetic RMAT / power-law) and
     register each with a long-lived ``repro.serve.CliqueService``;
  2. submit every tenant's queries CONCURRENTLY -- exact counts at
     several k, plus a materializing "top-N k-cliques containing vertex
     v" listing query per snapshot, each with its own latency deadline;
  3. the service coalesces ready tiles from different requests into
     shared fixed-shape device batches (continuous batching: fused
     batches reuse the same warm executables as single-query traffic),
     schedules pulls EDF-first under the deadlines, and routes exact
     counts / byte-identical clique rows back to per-request sinks;
  4. preprocessing is shared: each snapshot is truss-decomposed ONCE into
     a cached PipelinePlan (in-process keyed cache, plus an on-disk store
     with --plan-cache so a restarted service skips it entirely);
  5. the run ends with per-request latencies and the service's own
     accounting (fused batches, cross-request batches, deadline misses).

    PYTHONPATH=src python examples/clique_service.py --snapshots 3 --k 5
    # multi-device serving on a CPU host:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/clique_service.py --snapshots 3
"""
import argparse
import time

import numpy as np

from repro.data import powerlaw_graph, rmat_graph
from repro.obs import trace
from repro.obs.logging import LEVELS, setup_logging
from repro.serve import CliqueService


def snapshot(i: int):
    """Synthetic tenant graph stream: alternating RMAT / power-law."""
    if i % 2 == 0:
        return f"rmat-{i}", rmat_graph(11, 6, seed=100 + i)
    return f"powerlaw-{i}", powerlaw_graph(2500, 10, seed=100 + i)


def main():
    """Ingest snapshots, serve all tenants' queries concurrently."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshots", type=int, default=3)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--topn", type=int, default=5,
                    help="N for the top-N cliques-containing-v query")
    ap.add_argument("--deadline", type=float, default=30.0, metavar="S",
                    help="per-query latency deadline in seconds (EDF "
                         "scheduling + miss accounting, never cancellation)")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "lax", "ref", "autotune"],
                    help="kernel backend for all queries (default auto = "
                         "compiled lax on CPU hosts)")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="on-disk plan store: a restarted service reloads "
                         "each snapshot's truss order + tile tables "
                         "instead of re-decomposing (keyed by graph "
                         "content, see pipeline.cached_plan)")
    ap.add_argument("--tune-cache", default=None, metavar="DIR",
                    help="persistent autotuner directory (repro.tune): "
                         "a restarted service reuses tuned backend/geometry "
                         "records and XLA's persistent compilation cache "
                         "instead of re-measuring and re-compiling")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="chaos mode: seeded repro.resilience fault plan "
                         "(e.g. 'seed=7;*=0.1'); the service keeps every "
                         "answer exact via retry/demotion")
    ap.add_argument("--log-level", default="warning", choices=list(LEVELS),
                    help="repro.* logger verbosity (obs/logging)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto span trace of the whole "
                         "serving run (per-request async tracks included)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus /metrics from the service on "
                         "127.0.0.1:PORT (0 = ephemeral port)")
    args = ap.parse_args()
    setup_logging(args.log_level)
    if args.trace_out:
        trace.configure(enabled=True)
    if args.tune_cache:
        from repro import tune

        tune.configure(args.tune_cache)
    if args.fault_plan:
        from repro.resilience import inject

        inject.configure(args.fault_plan)
        print(f"fault injection: {args.fault_plan}")

    svc = CliqueService(backend=None if args.backend == "auto"
                        else args.backend,
                        plan_cache_dir=args.plan_cache,
                        metrics_port=args.metrics_port)
    if svc.metrics_address:
        print(f"metrics: {svc.metrics_address}/metrics")
    graphs = {}
    for i in range(args.snapshots):
        name, g = snapshot(i)
        graphs[name] = g
        svc.register_graph(name, g)

    # every tenant submits at once: two counting queries plus the top-N
    # materializing query per snapshot, all inside one serving pipeline
    t0 = time.time()
    tickets = []
    for name, g in graphs.items():
        for k in (args.k, args.k + 1):
            tickets.append((name, f"count k={k}",
                            svc.submit(name, k, "count",
                                       deadline_s=args.deadline)))
        v = int(np.argmax(g.degrees()))
        tickets.append((name, f"top-{args.topn} {args.k}-cliques @ v={v}",
                        svc.submit(name, args.k, "list", vertex_filter=v,
                                   max_out=args.topn,
                                   deadline_s=args.deadline)))
    print(f"submitted {len(tickets)} concurrent queries over "
          f"{len(graphs)} snapshots "
          f"({svc.engine_stats.backend or 'auto'} backend)")

    for name, what, ticket in tickets:
        res = ticket.result()
        late = " LATE" if res.deadline_missed else ""
        if res.kind == "count":
            g = graphs[name]
            print(f"[{name}] {what}: {res.count} "
                  f"({res.count / max(g.n, 1):.2f}/vertex, "
                  f"{res.latency_s * 1e3:.0f}ms{late})")
        else:
            first = (f" first={res.rows[0].tolist()}"
                     if res.rows.shape[0] else "")
            print(f"[{name}] {what}: {res.rows.shape[0]} found "
                  f"({res.latency_s * 1e3:.0f}ms{late}){first}")
    wall = time.time() - t0

    s = svc.stats
    print(f"served {s.completed} requests in {wall:.2f}s: "
          f"{s.fused_batches} device batches "
          f"({s.cross_request_batches} cross-request, "
          f"{s.fused_chunks} chunks fused, {s.spill_tiles} host spills), "
          f"{s.deadline_missed} deadline misses")
    svc.close()
    if args.trace_out:
        trace.export(args.trace_out)
        print(f"trace: wrote {args.trace_out} "
              f"({len(trace.events())} events)")


if __name__ == "__main__":
    main()
