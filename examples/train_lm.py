"""Train a small LM (~10M params) for a few hundred steps with the full
production stack: arch registry config, data pipeline, AdamW + schedule,
fault-tolerant loop with checkpoints.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import LMDataPipeline
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # granite family scaled to ~10M params for CPU
    base = configs.get("granite-3-8b").reduced
    cfg = dataclasses.replace(base, n_layers=4, d_model=128, n_heads=8,
                              n_kv_heads=4, d_head=16, d_ff=512, vocab=512)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.01,
                       schedule=cosine_schedule(20, args.steps))

    @jax.jit
    def jstep(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: tr.loss_fn(p, batch, cfg))(params)
        params, opt, m = adamw_update(g, opt, params, ocfg)
        return params, opt, {"loss": loss, **m}

    def step(params, opt, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = jstep(params, opt, batch)
        return params, opt, m

    pipe = LMDataPipeline(vocab=cfg.vocab, batch=8, seq_len=64, seed=0)
    loop = TrainLoop(TrainLoopConfig(total_steps=args.steps,
                                     checkpoint_dir=args.ckpt,
                                     checkpoint_every=100),
                     step, params, opt, pipe)
    first_loss = None
    out = loop.run()
    final = {k: float(np.asarray(v)) for k, v in out["metrics"].items()}
    print(f"finished at step {out['final_step']}: loss={final['loss']:.4f} "
          f"(stragglers logged: {len(out['stragglers'])})")


if __name__ == "__main__":
    main()
