"""Quickstart: list and count k-cliques with EBBkC.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ebbkc, vbbkc
from repro.core.graph import degeneracy_order
from repro.core.truss import truss_decomposition
from repro.data import planted_cliques

# build a graph: 5 planted 8-cliques + noise
g = planted_cliques(400, 5, 8, p_noise=0.01, seed=1)
td = truss_decomposition(g)
_, delta = degeneracy_order(g)
print(f"graph: n={g.n} m={g.m} tau={td.tau} delta={delta} "
      f"(Lemma 4.1: tau < delta -> {td.tau < delta})")

for k in (4, 5, 6):
    r = ebbkc.count(g, k, order="hybrid", et_t=3)          # EBBkC-H + ET
    v = vbbkc.count(g, k, variant="ddegcol")               # VBBkC baseline
    assert r.count == v.count
    print(f"k={k}: {r.count} cliques | EBBkC branches={r.stats.branches} "
          f"et_hits={r.stats.et_hits} vs VBBkC branches={v.stats.branches}")

# list the 6-cliques (bounded output buffer)
cliques, _ = ebbkc.list_cliques(g, 6, max_out=10)
print("first 6-cliques:", cliques[:3].tolist())

# accelerator engine; the kernel backend registry picks compiled jax.lax
# off-TPU (pass engine_kwargs={"backend": "pallas"} to pin the Pallas path)
r_dev = ebbkc.count(g, 5, backend="jax")
print(f"device engine agrees: {r_dev.count == ebbkc.count(g, 5).count} "
      f"(kernel backend: {r_dev.stats.backend})")
