"""Train a GIN whose node features are augmented with per-node k-clique
counts produced by the EBBkC operator -- the paper's technique feeding the
GNN substrate (higher-order structure as features, cf. paper Section 1's
motif applications).

    PYTHONPATH=src python examples/gnn_clique_features.py --steps 200
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ebbkc
from repro.data import planted_cliques
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update


def clique_features(g, ks=(3, 4)):
    """Per-node clique participation counts via the listing engine."""
    feats = np.zeros((g.n, len(ks)), np.float32)
    for j, k in enumerate(ks):
        cliques, _ = ebbkc.list_cliques(g, k)
        for row in cliques:
            feats[row, j] += 1.0
    return np.log1p(feats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # task: classify whether a node belongs to a planted clique
    g = planted_cliques(300, 6, 9, p_noise=0.02, seed=3)
    labels = np.zeros(g.n, np.int32)
    cliques, _ = ebbkc.list_cliques(g, 8)
    for row in cliques:
        labels[row] = 1
    deg = g.degrees().astype(np.float32)[:, None]
    cf = clique_features(g)
    feats = np.concatenate([deg / max(deg.max(), 1), cf], axis=1)
    edges = jnp.asarray(np.concatenate([g.edges.T, g.edges.T[::-1]], 1),
                        jnp.int32)
    mask = jnp.ones((edges.shape[1],), jnp.float32)

    cfg = gnn.GINConfig(n_layers=3, d_hidden=32, d_in=feats.shape[1],
                        n_classes=2)
    params = gnn.init_gin(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    X, Y = jnp.asarray(feats), jnp.asarray(labels)

    @jax.jit
    def step(params, opt):
        def lf(p):
            logits = gnn.gin_forward(p, X, edges, mask, cfg)
            oh = jax.nn.one_hot(Y, 2)
            return -(oh * jax.nn.log_softmax(logits)).sum(-1).mean()
        loss, grads = jax.value_and_grad(lf)(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for i in range(args.steps):
        params, opt, loss = step(params, opt)
        if i % 50 == 0 or i == args.steps - 1:
            logits = gnn.gin_forward(params, X, edges, mask, cfg)
            acc = float((jnp.argmax(logits, -1) == Y).mean())
            print(f"step {i}: loss={float(loss):.4f} acc={acc:.3f}")
    assert acc > 0.9, "clique features should make this easy"
    print("final accuracy:", acc)


if __name__ == "__main__":
    main()
