"""Insert the generated roofline tables into EXPERIMENTS.md placeholders."""
import re
import sys

sys.path.insert(0, ".")
from benchmarks.roofline_report import table  # noqa: E402


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    single = table("single")
    multi = table("multipod")
    text = re.sub(r"<!-- ROOFLINE_TABLE_SINGLE -->(.|\n)*?(?=\n\nMulti-pod)",
                  single, text, count=1)
    text = re.sub(r"<!-- ROOFLINE_TABLE_MULTI -->(.|\n)*?(?=\n\nReading)",
                  multi + "\n", text, count=1)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("tables inserted")


if __name__ == "__main__":
    main()
