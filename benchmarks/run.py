"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Host-engine timings run the
paper-faithful bitset recursions (python-int bitsets ~ the paper's bitmap
adjacency); relative comparisons between algorithms reproduce the paper's
figures.  The device-engine roofline projection uses the TPU v5e model of
EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [bench_name ...]

Multi-device dispatch sweep (front-end-to-finish wall clock per device
count, parity-checked -- exits non-zero if any device count disagrees):

    PYTHONPATH=src python -m benchmarks.run --devices 1,4 \\
        --graph rmat:12 --k 5 --json BENCH.json

The sweep forges virtual CPU devices itself when XLA_FLAGS is unset.

Autotuner integration: ``--tune-cache DIR`` activates the persistent
tuning cache (repro.tune) for the whole run; ``--phase cold|warm`` tags
every record so a second process sharing the cache can ``--append`` its
records to the same JSON and ``--assert-warm FACTOR`` that its summed
``tune_s + kernel_compile_s`` is at least FACTOR x cheaper than the cold
phase's (the CI warm-start gate).  ``--tune`` runs the budgeted geometry
search and records tuned-vs-default listing rows side by side.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from .common import emit, graph_suite, timed

from repro.core import ebbkc, vbbkc
from repro.core.graph import degeneracy_order, max_clique_size
from repro.core.truss import truss_decomposition


# ---------------------------------------------------------------------------
# Table 1: dataset statistics (validates tau < delta, the Lemma 4.1 claim)
# ---------------------------------------------------------------------------

def bench_dataset_stats():
    for name, g in graph_suite().items():
        td, dt_t = timed(truss_decomposition, g)
        (_, delta), _ = timed(degeneracy_order, g)
        omega = max_clique_size(g)
        deg = g.degrees().max() if g.n else 0
        assert td.tau < delta, f"Lemma 4.1 violated on {name}"
        emit(f"stats/{name}", dt_t,
             f"n={g.n};m={g.m};maxdeg={deg};delta={delta};tau={td.tau};"
             f"omega={omega};tau_lt_delta=True")


# ---------------------------------------------------------------------------
# Fig 4/5: runtime vs k -- EBBkC+ET against the VBBkC baselines
# ---------------------------------------------------------------------------

def bench_kclique_runtime():
    for name, g in graph_suite().items():
        for k in (4, 5, 6, 7, 8):
            r_e, t_e = timed(ebbkc.count, g, k, order="hybrid", et_t=3)
            r_v, t_v = timed(vbbkc.count, g, k, variant="ddegcol")
            r_d, t_d = timed(vbbkc.count, g, k, variant="degen")
            assert r_e.count == r_v.count == r_d.count
            emit(f"runtime/{name}/k{k}/ebbkc+et", t_e,
                 f"count={r_e.count};speedup_vs_ddegcol={t_v / t_e:.2f};"
                 f"speedup_vs_degen={t_d / t_e:.2f}")
            emit(f"runtime/{name}/k{k}/ddegcol", t_v, f"count={r_v.count}")
            emit(f"runtime/{name}/k{k}/degen", t_d, f"count={r_d.count}")


# ---------------------------------------------------------------------------
# Fig 6: ablation -- framework vs early-termination contributions
# ---------------------------------------------------------------------------

def bench_ablation():
    for name in ("ba3k", "plant"):
        g = graph_suite()[name]
        for k in (5, 7):
            r1, t1 = timed(ebbkc.count, g, k, order="hybrid", et_t=3)
            r2, t2 = timed(ebbkc.count, g, k, order="hybrid", et_t=0)
            r3, t3 = timed(vbbkc.count, g, k, variant="ddegcol+")
            r4, t4 = timed(vbbkc.count, g, k, variant="ddegcol")
            assert r1.count == r2.count == r3.count == r4.count
            emit(f"ablation/{name}/k{k}/ebbkc+et", t1,
                 f"branches={r1.stats.branches};et_hits={r1.stats.et_hits}")
            emit(f"ablation/{name}/k{k}/ebbkc", t2,
                 f"branches={r2.stats.branches}")
            emit(f"ablation/{name}/k{k}/ddegcol+rule2", t3,
                 f"branches={r3.stats.branches}")
            emit(f"ablation/{name}/k{k}/ddegcol", t4,
                 f"branches={r4.stats.branches}")


# ---------------------------------------------------------------------------
# Table 2: ordering generation time (truss vs degeneracy)
# ---------------------------------------------------------------------------

def bench_ordering_time():
    for name, g in graph_suite().items():
        _, t_truss = timed(truss_decomposition, g)
        _, t_degen = timed(degeneracy_order, g)
        emit(f"ordering/{name}/truss", t_truss,
             f"ratio_vs_degen={t_truss / max(t_degen, 1e-9):.2f}")
        emit(f"ordering/{name}/degeneracy", t_degen, "")


# ---------------------------------------------------------------------------
# Fig 7: the three edge orderings (T / C / H), all with ET
# ---------------------------------------------------------------------------

def bench_edge_orderings():
    for name in ("ba3k", "er1k"):
        g = graph_suite()[name]
        for k in (5, 6):
            res = {}
            for order in ("truss", "color", "hybrid"):
                r, t = timed(ebbkc.count, g, k, order=order, et_t=3)
                res[order] = (r, t)
            counts = {r.count for r, _ in res.values()}
            assert len(counts) == 1
            for order, (r, t) in res.items():
                emit(f"edge_order/{name}/k{k}/{order}", t,
                     f"branches={r.stats.branches};"
                     f"max_tile={r.max_tile}")


# ---------------------------------------------------------------------------
# Fig 8: effect of the new color Rule (2)
# ---------------------------------------------------------------------------

def bench_rule2():
    for name in ("ba3k", "rmat12"):
        g = graph_suite()[name]
        for k in (5, 7, 9):
            r2, t2 = timed(ebbkc.count, g, k, order="hybrid", et_t=3,
                           use_rule2=True)
            r0, t0 = timed(ebbkc.count, g, k, order="hybrid", et_t=3,
                           use_rule2=False)
            assert r2.count == r0.count
            emit(f"rule2/{name}/k{k}/with", t2,
                 f"pruned={r2.stats.pruned_color}")
            emit(f"rule2/{name}/k{k}/without", t0,
                 f"pruned={r0.stats.pruned_color};"
                 f"speedup={t0 / max(t2, 1e-9):.2f}")


# ---------------------------------------------------------------------------
# Fig 9: early-termination threshold t
# ---------------------------------------------------------------------------

def bench_et_t():
    g = graph_suite()["plant"]
    for k in (6, 9):
        base = None
        for t_plex in (0, 2, 3, 4, 5):
            r, t = timed(ebbkc.count, g, k, order="hybrid", et_t=t_plex)
            if base is None:
                base = r.count
            assert r.count == base
            emit(f"et_t/plant/k{k}/t{t_plex}", t,
                 f"et_hits={r.stats.et_hits};branches={r.stats.branches}")


# ---------------------------------------------------------------------------
# Fig 10: parallelization -- NP vs EP vs LPT-scheduled EP load balance
# ---------------------------------------------------------------------------

def bench_parallel():
    from repro.core import pipeline
    from repro.core import tiles as tiles_mod
    from repro.core.engine_np import Stats, count_rec_C, count_rec_V
    from repro.runtime.clique_scheduler import balanced_bins

    g = graph_suite()["ba3k"]
    k = 6
    # true per-unit work = measured branch count per top-level branch
    ep_costs = []
    for tile in pipeline.iter_tiles(g, k, mode="hybrid"):
        st = Stats()
        count_rec_C(tile.rows, (1 << tile.s) - 1, k - 2, st,
                    colors=tile.colors, et_t=3)
        ep_costs.append(st.branches + tile.s + 1)
    np_costs = []
    for tile in tiles_mod.vertex_tiles(g, k, colored=True):
        st = Stats()
        count_rec_V(tile.rows, (1 << tile.s) - 1, k - 1, st,
                    colors=tile.colors, et_t=3)
        np_costs.append(st.branches + tile.s + 1)
    for n_dev in (16, 64, 256):
        for scheme, costs in (("np", np_costs), ("ep", ep_costs)):
            # round-robin static assignment (the naive scheme)
            loads = np.zeros(n_dev)
            for i, c in enumerate(costs):
                loads[i % n_dev] += c
            rr = loads.max() / max(loads.mean(), 1e-9)
            _, lpt_loads = balanced_bins(costs, n_dev)
            lpt = lpt_loads.max() / max(lpt_loads.mean(), 1e-9)
            emit(f"parallel/ba3k/k{k}/{scheme}/dev{n_dev}", 0.0,
                 f"units={len(costs)};roundrobin_imbalance={rr:.3f};"
                 f"lpt_imbalance={lpt:.3f};"
                 f"parallel_efficiency={1 / lpt:.3f}")


# ---------------------------------------------------------------------------
# Pipeline front-end: reference vs vectorized extraction + stage breakdown
# ---------------------------------------------------------------------------

def bench_pipeline_stages():
    """Front-end comparison + per-stage timing on rmat12/k=5.

    "reference" is the pre-pipeline front-end (pure-Python extractor in
    ``core.tiles`` + per-bit packer), kept as the parity oracle;
    "vectorized" is ``core.pipeline``.  The e2e rows break the accelerator
    engine's wall-clock into extract / pack / device / combine stages and
    derive the end-to-end speedup of swapping front-ends under the same
    device compute.
    """
    from repro.core import engine_jax, pipeline
    from repro.core import tiles as tiles_mod

    g = graph_suite()["rmat12"]
    k = 5

    def reference_frontend():
        binned = {}
        for t in tiles_mod.edge_tiles(g, k, mode="hybrid"):
            T = next(b for b in pipeline.BINS if t.s <= b)
            binned.setdefault(T, []).append(t)
        return {T: engine_jax.pack_tiles(ts, T)
                for T, ts in sorted(binned.items())}

    ref, t_ref = timed(reference_frontend)
    batches, t_vec = timed(
        lambda: [b for b in pipeline.stream_batches(g, k, order="hybrid")])
    n_ref = sum(p.A.shape[0] for p in ref.values())
    n_vec = sum(b.B for b in batches if isinstance(b, pipeline.TileBatch))
    assert n_ref == n_vec, (n_ref, n_vec)
    emit("pipeline/rmat12/k5/frontend_reference", t_ref, f"tiles={n_ref}")
    emit("pipeline/rmat12/k5/frontend_vectorized", t_vec,
         f"tiles={n_vec};extract_speedup={t_ref / max(t_vec, 1e-9):.2f}")
    # parallel pack producer: same byte-identical stream, wall clock of
    # draining it with a free consumer (packing overlaps across workers)
    workers = pipeline.default_pack_workers()
    _, t_par = timed(
        lambda: [b for b in pipeline.stream_batches(
            g, k, order="hybrid", pack_workers=None)], repeat=2)
    emit("pipeline/rmat12/k5/frontend_parallel", t_par,
         f"tiles={n_vec};pack_workers={workers};"
         f"speedup_vs_serial={t_vec / max(t_par, 1e-9):.2f}")

    # serial packer + no plan cache: the seed-equivalent arithmetic below
    # subtracts stage seconds from wall-clock, so "pack" must be wall time
    # (parallel workers bill CPU-seconds) and the table build must stay in
    # the "extract" stage (the plan cache would move it to plan_build_s)
    stage = {}
    r, t_e2e = timed(engine_jax.count, g, k, interpret=True,
                     pack_workers=0, plan_cache=False,
                     stage_times=stage)
    breakdown = ";".join(
        f"{s}={stage.get(s, 0.0) * 1e6:.0f}us"
        for s in ("extract", "pack", "device", "combine"))
    emit(f"pipeline/rmat12/k{k}/e2e", t_e2e,
         f"count={r.count};tiles={r.tiles};{breakdown}")
    # seed-equivalent e2e: same device/combine stages, reference front-end
    t_front = stage.get("extract", 0.0) + stage.get("pack", 0.0)
    t_seed = t_e2e - t_front + t_ref
    emit(f"pipeline/rmat12/k{k}/e2e_seed_equiv", t_seed,
         f"frontend={t_ref * 1e6:.0f}us;"
         f"e2e_speedup={t_seed / max(t_e2e, 1e-9):.2f}")

    # emission subsystem on the same graph/k: stage breakdown + throughput
    stage_l = {}
    (_, lst), t_list = timed(
        lambda: ebbkc.list_cliques(
            g, k, backend="jax",
            engine_kwargs=dict(devices=1, stage_times=stage_l)))
    breakdown_l = ";".join(
        f"{s}={stage_l.get(s, 0.0) * 1e6:.0f}us"
        for s in ("extract", "pack", "device", "emit"))
    front_l = stage_l.get("extract", 0.0) + stage_l.get("pack", 0.0)
    emit(f"pipeline/rmat12/k{k}/listing_e2e", t_list,
         f"emitted={lst.emitted_cliques};"
         f"cliques_per_s={lst.emitted_cliques / max(t_list, 1e-9):.0f};"
         f"frontend_s={front_l:.3f};pack_workers={lst.pack_workers};"
         f"overflowed={lst.overflowed_tiles};"
         f"sink_bytes={lst.sink_bytes};{breakdown_l}")


# ---------------------------------------------------------------------------
# Multi-device dispatch: front-end-to-finish sweep over device counts
# ---------------------------------------------------------------------------

def bench_dispatch(graph_spec="rmat:12", ks=(5,), device_counts=None,
                   out_json=None, with_listing=False, baseline=None,
                   backends=("auto",), batch_size=256, phase=None,
                   append=False, assert_warm=None, extra_records=None):
    """Sweep `engine_jax.count(devices=n)` over device counts x backends.

    Times front-end-to-finish (extract + pack + device + combine, plan
    prebuilt) per (backend, device count) with double-buffered staging,
    emits the speedup vs the 1-device baseline, and verifies every cell
    produces the identical clique count -- any mismatch exits non-zero
    (the CI bench-smoke gate).

    With ``with_listing`` the sweep also runs the emission subsystem per
    (k, devices, backend): end-to-end listing throughput in cliques/s
    PLUS a kernel-stage-only row (``kernel_seconds`` = device wall time
    from ``stage_times``, i.e. excluding extract/pack/decode), so device
    time is attributable separately from staging.  ``baseline`` (a
    previously committed JSON, e.g. BENCH_pr4.json) diffs every matching
    record's count/emitted against this run -- a count regression fails
    loudly (non-zero exit).

    Every record carries the autotuner columns (``tune_s``,
    ``tune_cache_hit``, ``kernel_compile_s``) plus the roofline inputs
    (``device_flops``, ``device_bytes``).  ``phase`` tags the records
    (cold/warm); ``append`` merges them into an existing ``out_json``;
    ``assert_warm`` enforces the warm-start contract across the two
    phases (see :func:`assert_warm_start`).
    """
    import jax
    from repro.core import ebbkc, engine_jax, pipeline
    from repro.launch.clique import load_graph
    from repro.runtime.dispatch import resolve_devices

    import time as _time

    import jax.numpy as jnp
    import numpy as _np
    from repro.core import listing as listing_mod
    from repro.core import tiles as tiles_mod
    from repro.kernels import ops as kops

    counts = sorted(set(device_counts or {1, jax.device_count()}))
    if counts[0] != 1:
        counts = [1] + counts
    g = load_graph(graph_spec)
    # CSV-safe name: er:400,0.06 -> er400-0.06
    gname = graph_spec.replace(":", "").replace(",", "-")
    plan = pipeline.build_plan(g, order="hybrid")
    records = []
    mismatches = []

    def kernel_stage_listing(k, backend):
        """Pure device-stage listing throughput: pre-staged arrays, warmed
        jit caches, time ONLY the listing-kernel calls (the count pass
        that sizes the buffers is reported separately as sizing_s) --
        device time attributable apart from extract/pack/decode."""
        l = k - 2
        staged = []
        for item in pipeline.stream_batches(plan, k, order="hybrid",
                                            batch_size=batch_size):
            if isinstance(item, tiles_mod.Tile):
                continue  # oversize spills are host work, not kernel stage
            staged.append((jnp.asarray(item.A), jnp.asarray(item.cand)))
        t0 = _time.perf_counter()
        caps = []
        emitted = 0
        for A, cand in staged:
            cnt = _np.asarray(kops.count_tiles(A, cand, l, backend=backend))
            caps.append(listing_mod.capacity_for(cnt, listing_mod.MAX_CAPACITY))
            emitted += int(cnt.sum())
        sizing_s = _time.perf_counter() - t0
        for (A, cand), cap in zip(staged, caps):  # warmup: compile all sigs
            jax.block_until_ready(
                kops.list_tiles(A, cand, l, capacity=cap, backend=backend))
        kernel_s = float("inf")
        for _ in range(2):  # best of 2 (shared CI/container noise)
            t0 = _time.perf_counter()
            outs = [
                kops.list_tiles(A, cand, l, capacity=cap, backend=backend)
                for (A, cand), cap in zip(staged, caps)
            ]
            jax.block_until_ready(outs)
            kernel_s = min(kernel_s, _time.perf_counter() - t0)
        # drain first-call compile seconds accrued by the eager kernel
        # calls above so they are not misattributed to the next engine
        # record's kernel_compile_s
        kops.consume_compile_s()
        return emitted, kernel_s, sizing_s, len(staged)
    for k in ks:
        ref_count = None
        for backend in backends:
            base_t = None
            for n in counts:
                used = len(resolve_devices(n))
                # cold pass carries whatever compile this cell actually
                # pays (first-call signatures are process-wide, so later
                # cells legitimately report ~0); warm pass gives the
                # steady-state stage breakdown, timing is best of the two
                r_cold, t_cold = timed(engine_jax.count, g, k, plan=plan,
                                       devices=n, backend=backend,
                                       batch_size=batch_size)
                stage = {}
                r, t_warm = timed(engine_jax.count, g, k, plan=plan,
                                  devices=n, backend=backend,
                                  batch_size=batch_size,
                                  stage_times=stage)
                compile_s = (r_cold.stats.kernel_compile_s
                             + r.stats.kernel_compile_s)
                # tune events: sum the seconds over both passes, but the
                # hit verdict is the COLD pass's (the first resolution in
                # this process -- the warm pass always hits in-process)
                tune_s = r_cold.stats.tune_s + r.stats.tune_s
                tune_hit = r_cold.stats.tune_cache_hit
                t = min(t_cold, t_warm)
                if base_t is None:
                    base_t = t
                if ref_count is None:
                    ref_count = r.count
                elif r.count != ref_count:
                    mismatches.append((k, n, r.count, ref_count))
                speedup = base_t / max(t, 1e-9)
                dev_s = stage.get("device", 0.0)
                front_s = stage.get("extract", 0.0) + stage.get("pack", 0.0)
                emit(f"dispatch/{gname}/k{k}/{backend}/dev{n}", t,
                     f"count={r.count};tiles={r.tiles};devices_used={used};"
                     f"kernel_s={dev_s:.3f};frontend_s={front_s:.3f};"
                     f"overlap_s={r.stats.staging_overlap_s:.3f};"
                     f"compile_s={compile_s:.3f};"
                     f"tune_s={tune_s:.3f};tune_hit={tune_hit};"
                     f"pack_workers={r.stats.pack_workers};"
                     f"speedup_vs_dev1={speedup:.2f}")
                records.append({
                    "kind": "count", "backend": backend,
                    "graph": graph_spec, "k": k, "devices": n,
                    "devices_used": used, "seconds": t, "count": r.count,
                    "kernel_seconds": dev_s,
                    "frontend_s": front_s,
                    "pack_workers": r.stats.pack_workers,
                    "tiles": r.tiles, "spilled": r.stats.spilled_tiles,
                    "staging_overlap_s": r.stats.staging_overlap_s,
                    "kernel_compile_s": compile_s,
                    "tune_s": tune_s,
                    "tune_cache_hit": tune_hit,
                    "device_flops": sum(r.stats.device_flops.values()),
                    "device_bytes": sum(r.stats.device_bytes.values()),
                    "phase": phase,
                    "speedup_vs_dev1": speedup,
                })
                if not with_listing:
                    continue
                stage_l = {}
                lst_runs = []

                def run_listing():
                    out = ebbkc.list_cliques(
                        g, k, backend="jax", plan=plan,
                        engine_kwargs=dict(devices=n, backend=backend,
                                           batch_size=batch_size,
                                           stage_times=stage_l))
                    lst_runs.append(out[1])
                    return out
                # best of 2 like the count sweep: the serving model pays
                # kernel compiles once per process, not per query
                (_, lst), t_l = timed(run_listing, repeat=2)
                # like the count rows: seconds sum over the repeats, the
                # hit verdict is the first repeat's (process-cold)
                tune_s_l = sum(s.tune_s for s in lst_runs)
                tune_hit_l = lst_runs[0].tune_cache_hit
                compile_l = sum(s.kernel_compile_s for s in lst_runs)
                if lst.emitted_cliques != ref_count:
                    mismatches.append((k, n, lst.emitted_cliques, ref_count))
                rate = lst.emitted_cliques / max(t_l, 1e-9)
                # kernel-stage-only throughput: the device seconds actually
                # spent producing (count, overflow, buffer) triples --
                # attributable separately from staging/pack/decode; the
                # front-end (extract + pack worker seconds) is reported as
                # its own split so the Amdahl bottleneck is visible (the
                # stage dict accumulates over both repeats, hence /2)
                kern_s = stage_l.get("device", 0.0) / 2
                front_l = (stage_l.get("extract", 0.0)
                           + stage_l.get("pack", 0.0)) / 2
                kern_rate = lst.emitted_cliques / max(kern_s, 1e-9)
                emit(f"listing/{gname}/k{k}/{backend}/dev{n}", t_l,
                     f"emitted={lst.emitted_cliques};"
                     f"cliques_per_s={rate:.0f};"
                     f"kernel_s={kern_s:.3f};"
                     f"kernel_cliques_per_s={kern_rate:.0f};"
                     f"frontend_s={front_l:.3f};"
                     f"compile_s={compile_l:.3f};"
                     f"tune_s={tune_s_l:.3f};tune_hit={tune_hit_l};"
                     f"pack_workers={lst.pack_workers};"
                     f"queue_occ={lst.pack_queue_occupancy:.2f};"
                     f"overflowed={lst.overflowed_tiles};"
                     f"sink_bytes={lst.sink_bytes}")
                records.append({
                    "kind": "listing", "backend": backend,
                    "graph": graph_spec, "k": k, "devices": n,
                    "devices_used": used, "seconds": t_l,
                    "count": lst.emitted_cliques,
                    "cliques_per_s": rate,
                    "kernel_seconds": kern_s,
                    "kernel_cliques_per_s": kern_rate,
                    "frontend_s": front_l,
                    "pack_workers": lst.pack_workers,
                    "pack_queue_occupancy": lst.pack_queue_occupancy,
                    "overflowed_tiles": lst.overflowed_tiles,
                    "sink_bytes": lst.sink_bytes,
                    "kernel_compile_s": compile_l,
                    "tune_s": tune_s_l,
                    "tune_cache_hit": tune_hit_l,
                    "device_flops": sum(lst.device_flops.values()),
                    "device_bytes": sum(lst.device_bytes.values()),
                    "phase": phase,
                })
                if n != 1:
                    continue
                # kernel-stage-only row: device listing time in isolation
                # (emitted may undershoot ref_count when oversize tiles
                # spill to the host -- spills are not kernel-stage work)
                emitted_k, ks_s, sz_s, nb = kernel_stage_listing(k, backend)
                ks_rate = emitted_k / max(ks_s, 1e-9)
                emit(f"listing_kernel/{gname}/k{k}/{backend}/dev1", ks_s,
                     f"emitted={emitted_k};batches={nb};"
                     f"kernel_cliques_per_s={ks_rate:.0f};"
                     f"sizing_s={sz_s:.3f}")
                records.append({
                    "kind": "listing_kernel", "backend": backend,
                    "graph": graph_spec, "k": k, "devices": 1,
                    "devices_used": 1, "seconds": ks_s,
                    "count": emitted_k, "batches": nb,
                    "kernel_cliques_per_s": ks_rate,
                    "sizing_seconds": sz_s,
                })
    records.extend(extra_records or [])
    all_records = records
    if out_json:
        payload = {"graph": graph_spec, "ks": list(ks),
                   "device_counts": counts, "backends": list(backends),
                   "batch_size": batch_size,
                   "parity": not mismatches, "records": records}
        if append and os.path.exists(out_json):
            # second phase of a warm-start experiment: merge this run's
            # records into the cold run's JSON (phase field disambiguates)
            with open(out_json) as f:
                prior = json.load(f)
            payload["records"] = prior.get("records", []) + records
            payload["parity"] = payload["parity"] and prior.get("parity",
                                                                True)
            all_records = payload["records"]
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {out_json} ({len(payload['records'])} records)",
              file=sys.stderr)
    regressions = diff_against_baseline(records, baseline) if baseline else []
    if mismatches or regressions:
        for k, n, got, want in mismatches:
            print(f"PARITY FAILURE k={k} devices={n}: {got} != {want}",
                  file=sys.stderr)
        for k, n, got, want in regressions:
            print(f"BASELINE REGRESSION k={k} devices={n}: {got} != "
                  f"baseline {want}", file=sys.stderr)
        raise SystemExit(1)
    if assert_warm is not None:
        assert_warm_start(all_records, assert_warm)


def diff_against_baseline(records, baseline_path):
    """Compare this run's counts against a committed baseline JSON.

    Matches records on (kind, graph, k, devices, batch) -- counts must
    agree across backends by construction, so the backend is deliberately
    NOT part of the key: a lax run is diffed against a pallas-era baseline
    and vice versa.  ``batch`` (None for the static sweeps) keys the
    mutation benchmark's per-batch snapshots, whose counts evolve with the
    seeded churn.  Any count disagreement is flagged -- the regression
    gate of the CI bench-smoke job (the committed baseline is
    BENCH_pr10.json).  Records present on only one side are counted in
    the summary line but not fatal (the suites may differ in scope).
    """
    with open(baseline_path) as f:
        base = json.load(f)["records"]

    def key(r):
        return (r.get("kind", "count"), r["graph"], r["k"], r["devices"],
                r.get("batch"))

    base_by_key = {key(r): r for r in base}
    mismatches = []
    compared = 0
    run_only = 0
    for r in records:
        b = base_by_key.get(key(r))
        if b is None:
            run_only += 1
            continue
        compared += 1
        if r["count"] != b["count"]:
            mismatches.append((r["k"], r["devices"], r["count"], b["count"]))
    base_only = len(base) - compared
    print(f"# baseline {baseline_path}: {compared} records compared, "
          f"{len(mismatches)} count mismatches "
          f"({run_only} run-only / {base_only} baseline-only skipped)",
          file=sys.stderr)
    return mismatches


def assert_warm_start(records, factor):
    """The warm-start contract of the persistent tuning cache.

    ``records`` must hold both a ``phase == "cold"`` and a
    ``phase == "warm"`` population (two processes sharing one
    ``--tune-cache`` dir, the second run ``--append``-ed).  Asserts

    * every warm autotune record answered from the cache
      (``tune_cache_hit``, i.e. no live microbenchmark re-ran), and
    * the warm phase's summed one-time costs
      (``tune_s + kernel_compile_s``) are at least ``factor`` x smaller
      than the cold phase's -- the persisted records + XLA compilation
      cache actually skipped the measurements and the compiles.

    Counts across the phases are compared too: a warm process must
    reproduce the cold process's answers byte-for-byte.
    """
    def one_time(rs):
        return sum(r.get("kernel_compile_s", 0.0) + r.get("tune_s", 0.0)
                   for r in rs)

    cold = [r for r in records if r.get("phase") == "cold"]
    warm = [r for r in records if r.get("phase") == "warm"]
    if not cold or not warm:
        print("WARM-START FAILURE: need both cold- and warm-phase records "
              f"(got {len(cold)} cold / {len(warm)} warm)", file=sys.stderr)
        raise SystemExit(1)
    failures = []
    cold_by_key = {(r.get("kind"), r["graph"], r["k"], r["devices"]): r
                   for r in cold}
    for r in warm:
        key = (r.get("kind"), r["graph"], r["k"], r["devices"])
        c = cold_by_key.get(key)
        if c is not None and r["count"] != c["count"]:
            failures.append(f"count drift {key}: warm {r['count']} != "
                            f"cold {c['count']}")
        # a hit is owed only where the cold phase actually measured
        # something (e.g. k=3 counting is closed-form: no kernel, no
        # backend resolution, nothing to hit)
        if (r.get("backend") == "autotune"
                and not r.get("tune_cache_hit")
                and c is not None and c.get("tune_s", 0.0) > 0):
            failures.append(f"warm record {key} missed the tuning cache "
                            "(live microbenchmark re-ran)")
    cold_s, warm_s = one_time(cold), one_time(warm)
    ratio = cold_s / max(warm_s, 1e-9)
    print(f"# warm-start: cold tune+compile {cold_s:.3f}s, warm "
          f"{warm_s:.3f}s ({ratio:.1f}x, need >= {factor:g}x)",
          file=sys.stderr)
    if ratio < factor:
        failures.append(f"warm one-time costs only {ratio:.1f}x cheaper "
                        f"than cold (need >= {factor:g}x)")
    if failures:
        for msg in failures:
            print(f"WARM-START FAILURE: {msg}", file=sys.stderr)
        raise SystemExit(1)


# ---------------------------------------------------------------------------
# Dynamic graphs: incremental plan repair vs from-scratch rebuild (--mutate)
# ---------------------------------------------------------------------------

def bench_mutate(graph_spec="rmat:12", ks=(5,), n_batches=5, churn=0.01,
                 order="hybrid", seed=20250808, out_json=None, baseline=None,
                 append=False, assert_repair=None):
    """Edge-churn sweep: ``churn`` fraction of m mutated across
    ``n_batches`` seeded insert/delete batches (half inserts / half
    deletes each), every batch followed by an incremental
    :func:`repair_plan` AND a from-scratch :func:`pipeline.build_plan`
    of the mutated graph.  The touched-neighborhood closure is a
    constant factor wider than the batch itself (a deleted hub edge
    retires every tile over its common neighborhood), so the per-batch
    fraction is ``churn / n_batches`` -- on rmat:12 the default 1% total
    keeps every batch safely under the ``CHURN_THRESHOLD`` fallback.

    Per batch the sweep verifies (exits non-zero on any violation):

    * counts from the repaired plan == counts from the scratch plan for
      every k,
    * listed clique rows byte-identical (canonically sorted) between the
      two plans, and
    * the per-batch :func:`delta_cliques` gained/lost rows compose the
      previous snapshot's rows into exactly the new snapshot's rows.

    ``repair_s`` vs ``rebuild_s`` in the emitted records is the
    amortization claim of repro.delta (DESIGN.md 13); ``assert_repair``
    enforces ``sum(rebuild_s) / sum(repair_s) >= FACTOR`` over the
    repaired (non-fallback) batches -- the BENCH_pr10.json acceptance
    gate.  The batch stream is deterministic in ``seed``, so committed
    per-batch counts are diffable by :func:`diff_against_baseline`.
    """
    from repro.core import ebbkc, pipeline
    from repro.core.graph import apply_edge_batch
    from repro.delta import delta_cliques, repair_plan, rows_diff, rows_union
    from repro.delta.query import rows_sorted
    from repro.launch.clique import load_graph

    g = load_graph(graph_spec)
    gname = graph_spec.replace(":", "").replace(",", "-")
    rng = np.random.default_rng(seed)
    plan, build0_s = timed(pipeline.build_plan, g, order, repeat=2)
    emit(f"mutate/{gname}/plan_build", build0_s,
         f"n={g.n};m={g.m};order={order}")
    prev_rows = {k: rows_sorted(ebbkc.list_cliques(g, k, order=order,
                                                   plan=plan)[0])
                 for k in ks}
    records = []
    failures = []
    repair_total = rebuild_total = 0.0
    n_repaired = 0
    for b in range(n_batches):
        half = max(1, round(g.m * churn / (2 * n_batches)))
        # inserts: rejection-sample pairs not already edges (canonical
        # u < v), so the batch's nominal churn is not diluted by no-ops
        present = set(map(int, g.edge_keys()))
        ins = []
        while len(ins) < half:
            u, v = (int(x) for x in rng.integers(0, g.n, 2))
            if u == v:
                continue
            u, v = min(u, v), max(u, v)
            if u * g.n + v not in present:
                present.add(u * g.n + v)
                ins.append((u, v))
        dele = g.edges[rng.choice(g.m, half, replace=False)]
        g2 = apply_edge_batch(g, insert=np.asarray(ins, np.int64),
                              delete=dele)
        (plan2, info), repair_s = timed(repair_plan, plan, g2, order,
                                        repeat=2)
        scratch, rebuild_s = timed(pipeline.build_plan, g2, order, repeat=2)
        if not info.rebuilt:
            repair_total += repair_s
            rebuild_total += rebuild_s
            n_repaired += 1
        for k in ks:
            r_rep = ebbkc.count(g2, k, order=order, plan=plan2)
            r_scr = ebbkc.count(g2, k, order=order, plan=scratch)
            rows = rows_sorted(
                ebbkc.list_cliques(g2, k, order=order, plan=plan2)[0])
            srows = rows_sorted(
                ebbkc.list_cliques(g2, k, order=order, plan=scratch)[0])
            if r_rep.count != r_scr.count:
                failures.append(f"batch {b} k={k}: repaired count "
                                f"{r_rep.count} != scratch {r_scr.count}")
            if not np.array_equal(rows, srows):
                failures.append(f"batch {b} k={k}: repaired listing rows "
                                "differ from scratch rows")
            d, delta_s = timed(delta_cliques, plan, plan2, info, k,
                               order=order)
            composed = rows_union(rows_diff(prev_rows[k], d.lost), d.gained)
            if not np.array_equal(rows_sorted(composed), rows):
                failures.append(f"batch {b} k={k}: delta does not compose "
                                "prev snapshot into new snapshot")
            prev_rows[k] = rows
            speedup = rebuild_s / max(repair_s, 1e-9)
            emit(f"mutate/{gname}/k{k}/batch{b}", repair_s,
                 f"count={r_rep.count};rebuild_s={rebuild_s:.4f};"
                 f"repair_speedup={speedup:.2f};churn={info.churn:.4f};"
                 f"rebuilt={info.rebuilt};touched={info.touched_new.size};"
                 f"inserted={info.n_insert};deleted={info.n_delete};"
                 f"gained={d.gained.shape[0]};lost={d.lost.shape[0]};"
                 f"delta_query_s={delta_s:.4f}")
            records.append({
                "kind": "mutate", "graph": graph_spec, "k": k,
                "devices": 1, "batch": b, "order": order,
                "seconds": repair_s, "count": r_rep.count,
                "repair_s": repair_s, "rebuild_s": rebuild_s,
                "plan_build_s": rebuild_s,
                "repair_speedup": speedup,
                "churn": info.churn, "rebuilt": info.rebuilt,
                "touched_edges": int(info.touched_new.size),
                "inserted": info.n_insert, "deleted": info.n_delete,
                "delta_gained": int(d.gained.shape[0]),
                "delta_lost": int(d.lost.shape[0]),
                "delta_query_s": delta_s,
                "rows_identical": bool(np.array_equal(rows, srows)),
            })
        g, plan = g2, plan2
    agg = rebuild_total / max(repair_total, 1e-9)
    emit(f"mutate/{gname}/summary", repair_total,
         f"batches={n_batches};repaired={n_repaired};"
         f"rebuild_total_s={rebuild_total:.3f};"
         f"aggregate_repair_speedup={agg:.2f}")
    if out_json:
        payload = {"graph": graph_spec, "ks": list(ks),
                   "n_batches": n_batches, "churn": churn, "order": order,
                   "seed": seed, "parity": not failures,
                   "aggregate_repair_speedup": agg, "records": records}
        if append and os.path.exists(out_json):
            with open(out_json) as f:
                prior = json.load(f)
            prior["records"] = prior.get("records", []) + records
            prior["parity"] = prior.get("parity", True) and not failures
            prior["aggregate_repair_speedup"] = agg
            payload = prior
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {out_json} ({len(payload['records'])} records)",
              file=sys.stderr)
    if baseline:
        for k, n, got, want in diff_against_baseline(records, baseline):
            failures.append(f"baseline regression k={k} devices={n}: "
                            f"{got} != baseline {want}")
    if assert_repair is not None:
        if n_repaired < n_batches:
            failures.append(f"{n_batches - n_repaired} batches took the "
                            "rebuild fallback (repair gate needs the "
                            "repair path)")
        if agg < assert_repair:
            failures.append(f"aggregate repair speedup {agg:.2f}x < "
                            f"required {assert_repair:g}x")
    if failures:
        for msg in failures:
            print(f"MUTATE FAILURE: {msg}", file=sys.stderr)
        raise SystemExit(1)


# ---------------------------------------------------------------------------
# Geometry autotuner: tuned-vs-default side-by-side (the --tune sweep)
# ---------------------------------------------------------------------------

def bench_tune(graph_spec="rmat:12", ks=(5,), budget_s=20.0):
    """Run the budgeted geometry search, then time tuned vs default.

    The default row is measured FIRST (before the search persists its
    record) so its ``None`` knobs resolve to the hardcoded defaults, not
    to the freshly tuned record.  Both rows run the listing path on the
    same prebuilt plan, best of 2 (first pays any new-shape compiles).
    Returns the records (kind ``tune_geometry``); the coordinate
    descent's > 2% hysteresis means the tuned geometry never loses to
    the defaults by more than measurement noise on the tuning workload.
    """
    import dataclasses as _dc

    from repro.core import ebbkc, pipeline
    from repro.launch.clique import load_graph
    from repro.tune import search as tune_search

    g = load_graph(graph_spec)
    gname = graph_spec.replace(":", "").replace(",", "-")
    plan = pipeline.build_plan(g, order="hybrid")
    records = []

    def run_listing(k, geom):
        def go():
            return ebbkc.list_cliques(
                g, k, backend="jax", plan=plan,
                engine_kwargs=dict(
                    devices=1, batch_size=geom.batch_size,
                    bins=geom.bins, cap_policy=geom.cap_policy,
                    max_capacity=geom.max_capacity,
                    pack_workers=geom.pack_workers,
                    prefetch=geom.prefetch))
        (_, lst), t = timed(go, repeat=2)
        return lst, t

    for k in ks:
        l = k - 2
        lst_d, t_d = run_listing(k, tune_search.Geometry())
        rec = tune_search.tune_geometry("list", l, budget_s=budget_s)
        tuned = tune_search.geometry_from_record(rec)
        lst_t, t_t = run_listing(k, tuned)
        if lst_t.emitted_cliques != lst_d.emitted_cliques:
            print(f"PARITY FAILURE tune k={k}: tuned "
                  f"{lst_t.emitted_cliques} != default "
                  f"{lst_d.emitted_cliques}", file=sys.stderr)
            raise SystemExit(1)
        speedup = t_d / max(t_t, 1e-9)
        for variant, lst, t in (("default", lst_d, t_d),
                                ("tuned", lst_t, t_t)):
            geom = tuned if variant == "tuned" else tune_search.Geometry()
            emit(f"tune/{gname}/k{k}/{variant}", t,
                 f"emitted={lst.emitted_cliques};"
                 f"cliques_per_s={lst.emitted_cliques / max(t, 1e-9):.0f};"
                 f"t_policy={geom.t_policy};batch_size={geom.batch_size};"
                 f"cap_policy={geom.cap_policy};"
                 f"max_capacity={geom.max_capacity};"
                 f"pack_workers={geom.pack_workers}"
                 + (f";speedup_vs_default={speedup:.2f};"
                    f"search_s={rec.data['search_s']:.2f};"
                    f"evals={rec.data['evals']}"
                    if variant == "tuned" else ""))
            records.append({
                "kind": "tune_geometry", "variant": variant,
                "graph": graph_spec, "k": k, "devices": 1,
                "seconds": t, "count": lst.emitted_cliques,
                "cliques_per_s": lst.emitted_cliques / max(t, 1e-9),
                "geometry": _dc.asdict(geom),
                "kernel_compile_s": lst.kernel_compile_s,
                "tune_s": lst.tune_s,
            })
        records[-1]["speedup_vs_default"] = speedup
        records[-1]["search_s"] = rec.data["search_s"]
        records[-1]["search_evals"] = rec.data["evals"]
    return records


# ---------------------------------------------------------------------------
# Fig 11: space costs of the engine structures
# ---------------------------------------------------------------------------

def bench_space():
    from repro.core import engine_jax
    for name, g in graph_suite().items():
        binned, t = timed(engine_jax.bin_tiles, g, 5)
        tile_bytes = sum(p.A.nbytes + p.cand.nbytes
                         for p in binned.values())
        graph_bytes = g.edges.nbytes + g.indptr.nbytes + g.indices.nbytes
        emit(f"space/{name}", t,
             f"graph_bytes={graph_bytes};tile_bytes={tile_bytes};"
             f"ratio={tile_bytes / max(graph_bytes, 1):.2f}")


# ---------------------------------------------------------------------------
# Fig 12: scalability -- runtime vs graph size (RMAT scaling)
# ---------------------------------------------------------------------------

def bench_scalability():
    from repro.data import rmat_graph
    k = 5
    for scale in (10, 11, 12, 13):
        g = rmat_graph(scale, 6, seed=7)
        r, t = timed(ebbkc.count, g, k, order="hybrid", et_t=3)
        emit(f"scalability/rmat{scale}/k{k}", t,
             f"n={g.n};m={g.m};count={r.count}")


# ---------------------------------------------------------------------------
# Device engine: kernel-path comparison + roofline projection
# ---------------------------------------------------------------------------

def bench_device_engine():
    import jax.numpy as jnp
    from repro.core import engine_jax

    g = graph_suite()["ba3k"]
    k = 5
    ref = ebbkc.count(g, k).count
    binned, t_pack = timed(engine_jax.bin_tiles, g, k)
    bins_desc = ":".join(f"T{T}x{p.A.shape[0]}" for T, p in binned.items())
    emit(f"device/pack/ba3k/k{k}", t_pack, f"bins={bins_desc}")
    total = 0
    n_tiles = 0
    flops_mxu = 0
    for T, packed in binned.items():
        A, cand = jnp.asarray(packed.A), jnp.asarray(packed.cand)
        (hard, nv, t, f), dt = timed(
            engine_jax.count_packed, A, cand, k - 2, et=True,
            interpret=True)
        total += engine_jax.combine_counts(hard, nv, t, f, k - 2, True)
        n_tiles += packed.A.shape[0]
        flops_mxu += packed.A.shape[0] * 2 * T ** 3  # dense-tile matmul path
        emit(f"device/count/ba3k/k{k}/T{T}", dt,
             f"tiles={packed.A.shape[0]}")
    assert total == ref, (total, ref)
    # roofline projection: MXU path at 197 TFLOP/s
    peak = 197e12
    emit(f"device/roofline/ba3k/k{k}", flops_mxu / peak,
         f"tiles={n_tiles};mxu_flops={flops_mxu};"
         f"projected_tpu_seconds={flops_mxu / peak:.3e}")


ALL = [
    bench_dataset_stats, bench_kclique_runtime, bench_ablation,
    bench_ordering_time, bench_edge_orderings, bench_rule2, bench_et_t,
    bench_parallel, bench_pipeline_stages, bench_space, bench_scalability,
    bench_device_engine, bench_dispatch,
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*",
                    help="bench function names to run (default: all)")
    ap.add_argument("--devices", default=None,
                    help="comma list of device counts, e.g. 1,4: run the "
                         "multi-device dispatch sweep only")
    ap.add_argument("--graph", default="rmat:12",
                    help="graph spec for the dispatch sweep")
    ap.add_argument("--k", default="5",
                    help="comma list of clique sizes for the dispatch sweep")
    ap.add_argument("--json", default=None,
                    help="write dispatch-sweep records to this JSON file")
    ap.add_argument("--list", action="store_true", dest="with_listing",
                    help="also benchmark the emission subsystem per "
                         "(k, devices, backend): e2e + kernel-stage "
                         "cliques/s + emission stats")
    ap.add_argument("--backend", default="auto",
                    help="comma list of kernel backends to sweep "
                         "(auto/pallas/lax/autotune), e.g. lax,pallas")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (e.g. BENCH_pr4.json); "
                         "any count mismatch vs matching records exits "
                         "non-zero")
    ap.add_argument("--batch-size", type=int, default=256,
                    help="tile batch size for the dispatch sweep -- applied "
                         "to BOTH the e2e rows and the kernel-stage row, so "
                         "their in-run comparison stays apples-to-apples "
                         "(counts are batch-size-invariant, so baseline "
                         "diffs are unaffected)")
    ap.add_argument("--tune-cache", default=None, metavar="DIR",
                    help="persistent autotuner directory (repro.tune): "
                         "tuning records + XLA compilation cache shared "
                         "across processes; also settable via "
                         "REPRO_TUNE_CACHE")
    ap.add_argument("--tune", action="store_true",
                    help="with --devices: also run the budgeted geometry "
                         "search and record tuned-vs-default listing rows "
                         "side by side")
    ap.add_argument("--tune-budget", type=float, default=20.0,
                    help="search budget in seconds for --tune")
    ap.add_argument("--mutate", action="store_true",
                    help="run the dynamic-graph sweep instead: seeded "
                         "insert/delete batches on --graph, incremental "
                         "plan repair timed against a from-scratch rebuild "
                         "with byte-identical counts/listing rows enforced "
                         "at every batch")
    ap.add_argument("--mutate-batches", type=int, default=5,
                    help="number of edge-churn batches for --mutate")
    ap.add_argument("--mutate-churn", type=float, default=0.01,
                    help="total fraction of m mutated across the sweep "
                         "(split evenly over the batches, half inserts / "
                         "half deletes each)")
    ap.add_argument("--mutate-order", default="hybrid",
                    choices=["truss", "hybrid"],
                    help="edge ordering for --mutate (color always "
                         "rebuilds, so it is not a repair benchmark)")
    ap.add_argument("--mutate-seed", type=int, default=20250808,
                    help="RNG seed for the --mutate batch stream (the "
                         "committed baseline's per-batch counts are only "
                         "reproducible under the same seed)")
    ap.add_argument("--assert-repair", type=float, default=None,
                    metavar="FACTOR",
                    help="with --mutate: require the aggregate "
                         "rebuild_s/repair_s over all batches to be >= "
                         "FACTOR (exits non-zero otherwise)")
    ap.add_argument("--phase", default=None, choices=["cold", "warm"],
                    help="tag this run's records (cold = first process on "
                         "a tune cache, warm = a later one)")
    ap.add_argument("--append", action="store_true",
                    help="merge this run's records into an existing --json "
                         "file instead of overwriting it")
    ap.add_argument("--assert-warm", type=float, default=None,
                    metavar="FACTOR",
                    help="after the sweep, require the warm-phase records' "
                         "summed tune_s+kernel_compile_s to be >= FACTOR x "
                         "smaller than the cold phase's (reads the merged "
                         "--json records; exits non-zero on violation)")
    args = ap.parse_args()
    if args.tune_cache:
        from repro import tune
        tune.configure(args.tune_cache)
    print("name,us_per_call,derived")
    if args.mutate:
        ks = tuple(int(x) for x in args.k.split(","))
        bench_mutate(graph_spec=args.graph, ks=ks,
                     n_batches=args.mutate_batches,
                     churn=args.mutate_churn, order=args.mutate_order,
                     seed=args.mutate_seed, out_json=args.json,
                     baseline=args.baseline, append=args.append,
                     assert_repair=args.assert_repair)
        return
    if args.devices:
        counts = [int(x) for x in args.devices.split(",")]
        # XLA_FLAGS must be in the environment before the backend
        # initializes; forge enough virtual CPU devices for the sweep
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={max(counts)}")
        ks = tuple(int(x) for x in args.k.split(","))
        extra = (bench_tune(graph_spec=args.graph, ks=ks,
                            budget_s=args.tune_budget)
                 if args.tune else None)
        bench_dispatch(graph_spec=args.graph, ks=ks, device_counts=counts,
                       out_json=args.json, with_listing=args.with_listing,
                       baseline=args.baseline,
                       backends=tuple(args.backend.split(",")),
                       batch_size=args.batch_size, phase=args.phase,
                       append=args.append, assert_warm=args.assert_warm,
                       extra_records=extra)
        return
    wanted = set(args.benches)
    for fn in ALL:
        if wanted and fn.__name__ not in wanted:
            continue
        fn()


if __name__ == "__main__":
    main()
