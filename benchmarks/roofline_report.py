"""Render the EXPERIMENTS.md roofline table from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]

With ``--bench BENCH.json`` (a ``benchmarks.run --json`` artifact) the
report instead renders a *measured* kernel roofline: achieved FLOP/s and
bytes/s from each record's ``device_flops`` / ``device_bytes`` (the
packed ``(B, T, W)`` staging layout, accounted by the dispatcher) over
its device-stage seconds, next to the TPU-model projection for the same
work -- so the dry-run projections and the live kernel benchmarks share
one table format.

    PYTHONPATH=src python -m benchmarks.roofline_report --bench BENCH_pr6.json

With ``--trace trace.json`` (a ``--trace-out`` artifact from
``launch.clique`` or ``benchmarks.loadgen``) the report renders a
per-kernel-signature roofline straight from the span trace: the
dispatcher's device spans carry ``sig``/``flops``/``bytes`` args, so one
exported trace is enough to attribute achieved FLOP/s per kernel shape
(``repro.obs.profile.aggregate_device_spans``).

    PYTHONPATH=src python -m benchmarks.roofline_report --trace trace.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def load(mesh: str, out="artifacts/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def table(mesh: str, out="artifacts/dryrun"):
    rows = []
    head = ("| arch | shape | compute | memory | collective | dominant | "
            "HBM GB/dev | model/HLO flops | note |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in load(mesh, out):
        name = f"| {r['arch']} | {r['shape']} "
        if r["status"] == "skipped":
            rows.append(name + "| -- | -- | -- | skipped | -- | -- | "
                        f"{r['reason'][:60]}... |")
            continue
        if r["status"] != "ok":
            rows.append(name + "| ERROR ||||||" + r.get("error", "")[:40] +
                        " |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 1e9
        ratio = r.get("model_over_hlo_flops")
        ratio_s = f"{ratio:.2f}" if ratio else "--"
        rows.append(
            name + f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | {t['dominant'][:-2]} "
            f"| {hbm:.1f} | {ratio_s} |  |")
    return "\n".join(rows)


def bench_table(bench_path: str) -> str:
    """Measured kernel roofline from a ``benchmarks.run --json`` artifact.

    Per record (count / listing rows that carry the accounting fields):
    achieved FLOP/s = ``device_flops`` / device-stage seconds, achieved
    bytes/s = ``device_bytes`` (the packed ``(B, T*W + W)`` uint32 tile
    layout staged to devices) over the same seconds, the arithmetic
    intensity, and the TPU-model projection (``launch.roofline``) for the
    identical work.  Rows without kernel-stage accounting are skipped.
    """
    from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, roofline_terms)

    with open(bench_path) as f:
        records = json.load(f)["records"]
    rows = [
        "| record | phase | kernel_s | GFLOP | MB | achieved GFLOP/s | "
        "achieved GB/s | FLOP/byte | TPU bound | dominant |",
        "|" + "---|" * 10,
    ]
    skipped = 0
    for r in records:
        flops = r.get("device_flops") or 0
        nbytes = r.get("device_bytes") or 0
        secs = r.get("kernel_seconds") or 0
        if not flops or not secs:
            skipped += 1
            continue
        name = (f"{r.get('kind', 'count')}/{r['graph']}/k{r['k']}"
                f"/{r.get('backend', '?')}/dev{r['devices']}")
        t = roofline_terms(flops, nbytes, 0.0)
        rows.append(
            f"| {name} | {r.get('phase') or '--'} | {fmt_s(secs)} "
            f"| {flops / 1e9:.2f} | {nbytes / 1e6:.2f} "
            f"| {flops / secs / 1e9:.2f} | {nbytes / secs / 1e9:.2f} "
            f"| {flops / max(nbytes, 1):.1f} | {fmt_s(t['bound_s'])} "
            f"| {t['dominant'][:-2]} |")
    rows.append(f"\nmodel: {PEAK_FLOPS / 1e12:.0f} TFLOP/s, "
                f"{HBM_BW / 1e9:.0f} GB/s HBM; {skipped} records without "
                "kernel-stage accounting skipped")
    return "\n".join(rows)


def trace_table(trace_path: str) -> str:
    """Per-kernel-signature roofline from an exported span trace.

    Every dispatcher device span carries the kernel signature plus the
    staged flops/bytes in its args; ``aggregate_device_spans`` folds the
    trace into the same rows as the live ``kernel_records()`` table, so
    compile time, device seconds, and achieved FLOP/s are attributed per
    kernel shape from the trace file alone -- no rerun needed.
    """
    from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, roofline_terms)
    from repro.obs.profile import aggregate_device_spans

    with open(trace_path) as f:
        doc = json.load(f)
    rows = [
        "| kernel signature | calls | compile_s | device_s | GFLOP | MB | "
        "achieved GFLOP/s | achieved GB/s | TPU bound | dominant |",
        "|" + "---|" * 10,
    ]
    recs = aggregate_device_spans(doc)
    for r in recs:
        secs = r["execute_s"]
        flops, nbytes = r["flops"], r["bytes"]
        if not secs:
            continue
        t = roofline_terms(flops, nbytes, 0.0)
        rows.append(
            f"| {r['sig']} | {r['calls']} | {fmt_s(r['compile_s'])} "
            f"| {fmt_s(secs)} | {flops / 1e9:.2f} | {nbytes / 1e6:.2f} "
            f"| {flops / secs / 1e9:.2f} | {nbytes / secs / 1e9:.2f} "
            f"| {fmt_s(t['bound_s'])} | {t['dominant'][:-2]} |")
    rows.append(f"\nmodel: {PEAK_FLOPS / 1e12:.0f} TFLOP/s, "
                f"{HBM_BW / 1e9:.0f} GB/s HBM; {len(recs)} signatures in "
                f"{trace_path}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--bench", default=None, metavar="JSON",
                    help="render the measured-kernel roofline from a "
                         "benchmarks.run --json artifact instead of the "
                         "dry-run table")
    ap.add_argument("--trace", default=None, metavar="JSON",
                    help="render a per-kernel-signature roofline from a "
                         "--trace-out span trace (launch.clique or "
                         "benchmarks.loadgen artifact)")
    args = ap.parse_args()
    if args.trace:
        print(trace_table(args.trace))
        return
    if args.bench:
        print(bench_table(args.bench))
        return
    print(table(args.mesh, args.out))


if __name__ == "__main__":
    main()
