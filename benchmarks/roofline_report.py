"""Render the EXPERIMENTS.md roofline table from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(mesh: str, out="artifacts/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def table(mesh: str, out="artifacts/dryrun"):
    rows = []
    head = ("| arch | shape | compute | memory | collective | dominant | "
            "HBM GB/dev | model/HLO flops | note |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in load(mesh, out):
        name = f"| {r['arch']} | {r['shape']} "
        if r["status"] == "skipped":
            rows.append(name + "| -- | -- | -- | skipped | -- | -- | "
                        f"{r['reason'][:60]}... |")
            continue
        if r["status"] != "ok":
            rows.append(name + "| ERROR ||||||" + r.get("error", "")[:40] +
                        " |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 1e9
        ratio = r.get("model_over_hlo_flops")
        ratio_s = f"{ratio:.2f}" if ratio else "--"
        rows.append(
            name + f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | {t['dominant'][:-2]} "
            f"| {hbm:.1f} | {ratio_s} |  |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    print(table(args.mesh, args.out))


if __name__ == "__main__":
    main()
