"""Shared benchmark fixtures: graph suite + timing."""
from __future__ import annotations

import functools
import sys
import time
from typing import Callable, Dict

import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.graph import Graph  # noqa: E402
from repro.data import (erdos_renyi, planted_cliques, powerlaw_graph,  # noqa: E402
                        rmat_graph)


@functools.lru_cache(maxsize=None)
def graph_suite() -> Dict[str, Graph]:
    """Offline analogues of the paper's Table 1 regimes.

    power-law graphs: tau/delta clearly < 1 (the WK/PO/SO social family);
    planted-clique graphs: tau ~ delta (the dense DB/CI/WE family);
    RMAT: skewed web-like; ER: homogeneous baseline.
    """
    return {
        "ba3k": powerlaw_graph(3000, 12, seed=3),
        "er1k": erdos_renyi(1000, 0.03, seed=1),
        "rmat12": rmat_graph(12, 6, seed=7),
        "plant": planted_cliques(1500, 12, 14, p_noise=0.004, seed=5),
    }


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
