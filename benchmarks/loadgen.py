"""Open/closed-loop load generator for the serving tier.

Drives a mixed count+listing workload at ``repro.serve.CliqueService``
(and, for comparison, at a serial one-query-at-a-time executor built on
the plain engines), measuring per-request latency (p50/p90/p99), goodput
(deadline-meeting completions per second), deadline-miss rate, and
backpressure rejections -- and verifying every response against a
precomputed oracle (exact counts; byte-identical clique rows).  Results
land in the BENCH json format with the full loadgen config recorded in
each row, so serving capacity is a tracked number like every other
benchmark.

Closed loop (the default): ``--clients N`` threads each submit
``--requests-per-client M`` requests back to back (a new request only
after the previous response).  Open loop: ``--rates R1,R2,...`` sweeps
Poisson arrivals at each rate for ``--duration`` seconds; arrivals that
find the admission queue full are shed and counted as rejected.

    # the BENCH_pr7.json acceptance run: 8-client closed loop, serve vs
    # serial, >= 1.5x goodput at no worse p99
    PYTHONPATH=src python -m benchmarks.loadgen --mode both --clients 8 \\
        --requests-per-client 4 --graphs rmat:8,er:300,0.08 --ks 4,5 \\
        --list-frac 0.4 --json BENCH_pr7.json --assert-goodput-x 1.5

    # CI serve-smoke: short mixed workload at 1 and 4 virtual devices
    PYTHONPATH=src python -m benchmarks.loadgen --virtual-devices 4 \\
        --clients 4 --requests-per-client 2 --graphs rmat:8 --ks 4,5 \\
        --list-frac 0.5 --json serve_smoke.json

The workload is fully seeded: the same ``--seed`` produces the same
request multiset in every mode, which is what makes the serve-vs-serial
goodput ratio and the oracle comparison meaningful.
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def build_workload(graphs, ks, n_requests, list_frac, filter_frac, max_out,
                   deadline_ms, seed):
    """The seeded request multiset: one spec dict per request.

    Specs cycle deterministically through the graph/k grid with a
    seeded RNG choosing mode/filter, so every mode of every run on the
    same seed serves exactly the same work.
    """
    rng = np.random.default_rng(seed)
    specs = []
    for i in range(n_requests):
        gname = graphs[i % len(graphs)]
        k = int(ks[(i // len(graphs)) % len(ks)])
        is_list = bool(rng.random() < list_frac)
        spec = {
            "graph": gname,
            "k": k,
            "mode": "list" if is_list else "count",
            "vertex_filter": None,
            "max_out": None,
            "deadline_s": deadline_ms / 1e3 if deadline_ms else None,
        }
        if is_list and rng.random() < filter_frac:
            spec["vertex_filter"] = int(rng.integers(0, 64))
        if is_list and max_out:
            spec["max_out"] = int(max_out)
        specs.append(spec)
    return specs


def build_oracle(graph_objs, specs, backend):
    """Exact expected answers plus per-spec solo latencies.

    Counts come from ``engine_jax.count``; listing rows from one full
    ``stream_cliques`` per (graph, k), filtered/truncated with the same
    ``apply_vertex_filter``-then-``max_out`` semantics the service uses.
    Each distinct (graph, k, mode) is run twice -- warm executables,
    then a timed run -- so ``solo_s`` is the request's isolated warm
    latency, the basis of proportional SLOs (``--deadline-x``).

    Returns ``(oracle, solo_s)``: expected result per spec key, and
    isolated seconds per ``(graph, k, mode)``.
    """
    from repro.core import engine_jax, listing
    from repro.serve import apply_vertex_filter

    counts = {}
    rows = {}
    solo = {}
    oracle = {}
    for spec in specs:
        key = _spec_key(spec)
        g = graph_objs[spec["graph"]]
        gkm = (spec["graph"], spec["k"], spec["mode"])
        if spec["mode"] == "count":
            if gkm not in solo:
                engine_jax.count(g, spec["k"], backend=backend)  # warm
                t0 = time.perf_counter()
                counts[gkm] = engine_jax.count(g, spec["k"],
                                               backend=backend).count
                solo[gkm] = time.perf_counter() - t0
            if key not in oracle:
                oracle[key] = counts[gkm]
        else:
            if gkm not in solo:
                sink = listing.ArraySink(spec["k"])
                listing.stream_cliques(g, spec["k"], sink, backend=backend)
                rows[gkm] = sink.result()  # warm run doubles as reference
                sink = listing.ArraySink(spec["k"])
                t0 = time.perf_counter()
                listing.stream_cliques(g, spec["k"], sink, backend=backend)
                solo[gkm] = time.perf_counter() - t0
            if key not in oracle:
                expect = rows[gkm]
                if spec["vertex_filter"] is not None:
                    expect = apply_vertex_filter(expect, spec["vertex_filter"])
                if spec["max_out"] is not None:
                    expect = expect[: spec["max_out"]]
                oracle[key] = expect
    return oracle, solo


def _spec_key(spec):
    return (spec["graph"], spec["k"], spec["mode"], spec["vertex_filter"],
            spec["max_out"])


def _check(spec, result, oracle):
    """True when one response matches its oracle entry exactly."""
    want = oracle[_spec_key(spec)]
    if spec["mode"] == "count":
        return result.count == want
    return np.array_equal(result.rows, want)


class SerialExecutor:
    """The serve-tier baseline: one worker, one full query at a time.

    Mirrors the service's client API (``submit`` -> ticket with
    ``result(timeout)``) and its admission bound, but executes each
    request with a plain ``engine_jax.count`` / ``stream_cliques`` call
    -- the pre-serving ``examples/clique_service.py`` behavior.  Latency
    includes queue wait, so an 8-client burst pays the serialization.
    """

    class _Ticket:
        """Future-like handle of one queued serial request."""

        def __init__(self):
            self.event = threading.Event()
            self.result = None
            self.error = None

        def done(self):
            """True once the worker resolved this request."""
            return self.event.is_set()

        def get(self, timeout=None):
            """Block for the RequestResult (or re-raise the failure)."""
            if not self.event.wait(timeout):
                raise TimeoutError("serial request not resolved")
            if self.error is not None:
                raise self.error
            return self.result

    def __init__(self, graph_objs, devices, backend, max_pending=256):
        from repro.serve import ServiceOverloaded

        self._graphs = graph_objs
        self._devices = devices
        self._backend = backend
        self._q = queue.Queue()
        self._max_pending = max_pending
        self._overloaded = ServiceOverloaded
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def submit(self, spec, block=True):
        """Enqueue one spec; returns a ticket (queue-bounded like serve)."""
        if self._q.qsize() >= self._max_pending and not block:
            raise self._overloaded("serial queue full")
        t = (time.monotonic(), spec, self._Ticket())
        self._q.put(t)
        return t[2]

    def close(self):
        """Stop the worker after the queue drains."""
        self._q.put(None)
        self._thread.join()

    def _run(self):
        from repro.core import engine_jax, listing
        from repro.serve import RequestResult, apply_vertex_filter

        while True:
            item = self._q.get()
            if item is None:
                return
            t0, spec, ticket = item
            g = self._graphs[spec["graph"]]
            try:
                if spec["mode"] == "count":
                    r = engine_jax.count(g, spec["k"], devices=self._devices,
                                         backend=self._backend)
                    res = RequestResult(kind="count", count=r.count,
                                        stats=r.stats)
                else:
                    vf = spec["vertex_filter"]
                    if vf is None:
                        sink = listing.ArraySink(spec["k"],
                                                 max_out=spec["max_out"])
                        listing.stream_cliques(
                            g, spec["k"], sink, devices=self._devices,
                            backend=self._backend)
                        rows = sink.result()
                    else:
                        sink = listing.ArraySink(spec["k"])
                        listing.stream_cliques(
                            g, spec["k"], sink, devices=self._devices,
                            backend=self._backend)
                        rows = apply_vertex_filter(sink.result(), vf)
                        if spec["max_out"] is not None:
                            rows = rows[: spec["max_out"]]
                    res = RequestResult(kind="list", rows=rows,
                                        emitted=rows.shape[0])
                now = time.monotonic()
                res.latency_s = now - t0
                res.deadline_s = spec["deadline_s"]
                res.deadline_missed = (spec["deadline_s"] is not None
                                       and res.latency_s > spec["deadline_s"])
                ticket.result = res
            except BaseException as exc:
                ticket.error = exc
            ticket.event.set()


def _submit_serve(svc, spec, block=True):
    return svc.submit(spec["graph"], spec["k"], spec["mode"],
                      vertex_filter=spec["vertex_filter"],
                      max_out=spec["max_out"],
                      deadline_s=spec["deadline_s"], block=block)


HIST_EDGES_MS = [2.0 ** e for e in range(-1, 15)]  # 0.5ms .. 16s


def stage_breakdown(stage_dicts):
    """Aggregate per-request ``stage_s`` dicts into per-stage summaries.

    Returns ``{stage: {mean_ms, p99_ms, total_s, requests}}`` over the
    requests that recorded that stage (queue-wait vs fuse-wait vs device
    vs reorder; device seconds overlap across fused requests, so totals
    are attribution, not wall time).
    """
    per_stage = {}
    for d in stage_dicts:
        for stage, dt in (d or {}).items():
            per_stage.setdefault(stage, []).append(dt)
    out = {}
    for stage, vals in sorted(per_stage.items()):
        ms = np.asarray(vals) * 1e3
        out[stage] = {
            "mean_ms": float(ms.mean()),
            "p99_ms": float(np.percentile(ms, 99)),
            "total_s": float(ms.sum() / 1e3),
            "requests": int(ms.size),
        }
    return out


def summarize(name, latencies_s, missed, mismatches, rejected, wall_s):
    """Fold one run's raw measurements into a BENCH record body."""
    lat_ms = np.asarray(sorted(latencies_s)) * 1e3
    completed = lat_ms.size
    good = completed - missed
    hist, _ = (np.histogram(lat_ms, bins=[0.0] + HIST_EDGES_MS)
               if completed else (np.zeros(len(HIST_EDGES_MS), np.int64),
                                  None))
    rec = {
        "mode": name,
        "requests": completed + rejected,
        "completed": completed,
        "rejected": rejected,
        "mismatches": mismatches,
        "seconds": wall_s,
        "goodput_rps": good / wall_s if wall_s > 0 else 0.0,
        "throughput_rps": completed / wall_s if wall_s > 0 else 0.0,
        "deadline_missed": missed,
        "miss_rate": missed / completed if completed else 0.0,
        "p50_ms": float(np.percentile(lat_ms, 50)) if completed else 0.0,
        "p90_ms": float(np.percentile(lat_ms, 90)) if completed else 0.0,
        "p99_ms": float(np.percentile(lat_ms, 99)) if completed else 0.0,
        "mean_ms": float(lat_ms.mean()) if completed else 0.0,
        "latency_hist_edges_ms": HIST_EDGES_MS,
        "latency_hist": [int(x) for x in hist],
    }
    return rec


def run_closed(submit, specs, clients, oracle, timeout=600.0):
    """Closed-loop drive: ``clients`` threads, each spec waits its turn.

    ``submit(spec)`` must return a ticket with ``result``/``get``;
    returns (latencies, missed, mismatches, wall_s).  The executor is
    left open so warmup epochs and the measured epoch share one
    steady-state service (warm plans, warm executables).
    """
    per_client = [specs[c::clients] for c in range(clients)]
    lock = threading.Lock()
    latencies, missed, mismatches = [], [0], [0]
    stages = []
    errors = []

    def client(idx):
        try:
            for spec in per_client[idx]:
                ticket = submit(spec)
                res = (ticket.result(timeout) if hasattr(ticket, "result")
                       and not hasattr(ticket, "get") else ticket.get(timeout))
                with lock:
                    latencies.append(res.latency_s)
                    stages.append(dict(getattr(res, "stage_s", None) or {}))
                    if res.deadline_missed:
                        missed[0] += 1
                    if not _check(spec, res, oracle):
                        mismatches[0] += 1
        except BaseException as exc:  # surfaced after join
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(clients)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise errors[0]
    return latencies, missed[0], mismatches[0], wall, stages


def run_open(submit, specs, rate, oracle, seed, timeout=600.0):
    """Open-loop drive: Poisson arrivals at ``rate``/s, non-blocking admit.

    Overloaded submissions are shed (rejected); returns
    (latencies, missed, mismatches, rejected, wall_s, stage_dicts).
    """
    from repro.serve import ServiceOverloaded

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(specs))
    inflight = []
    rejected = 0
    t0 = time.monotonic()
    due = t0
    for spec, gap in zip(specs, gaps):
        due += gap
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        try:
            inflight.append((spec, submit(spec, block=False)))
        except ServiceOverloaded:
            rejected += 1
    latencies, missed, mismatches = [], 0, 0
    stages = []
    for spec, ticket in inflight:
        res = (ticket.result(timeout) if hasattr(ticket, "result")
               and not hasattr(ticket, "get") else ticket.get(timeout))
        latencies.append(res.latency_s)
        stages.append(dict(getattr(res, "stage_s", None) or {}))
        if res.deadline_missed:
            missed += 1
        if not _check(spec, res, oracle):
            mismatches += 1
    wall = time.monotonic() - t0
    return latencies, missed, mismatches, rejected, wall, stages


def main(argv=None):
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", default="serve",
                    choices=["serve", "serial", "both"])
    ap.add_argument("--loop", default="closed", choices=["closed", "open"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests-per-client", type=int, default=4)
    ap.add_argument("--rates", default="4",
                    help="open loop: comma-separated arrivals/s sweep")
    ap.add_argument("--duration", type=float, default=8.0,
                    help="open loop: seconds of arrivals per rate")
    ap.add_argument("--graphs", default="rmat:8",
                    help="comma-separated launch.clique load_graph specs")
    ap.add_argument("--ks", default="4,5")
    ap.add_argument("--list-frac", type=float, default=0.4)
    ap.add_argument("--filter-frac", type=float, default=0.25,
                    help="fraction of listing requests with a vertex filter")
    ap.add_argument("--max-out", type=int, default=0,
                    help="max_out on listing requests (0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="fixed per-request latency deadline (0 = none)")
    ap.add_argument("--deadline-x", type=float, default=0.0,
                    help="proportional SLO: deadline = X * the spec's "
                         "measured solo latency (0 = off; overrides "
                         "--deadline-ms)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos mode: inject seeded faults at every "
                         "repro.resilience site with this probability "
                         "during the serve-mode run (the oracle and the "
                         "serial baseline stay fault-free); results must "
                         "still match the oracle exactly")
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="seed of the deterministic fault schedule")
    ap.add_argument("--devices", default="all")
    ap.add_argument("--virtual-devices", type=int, default=0,
                    help="forge N virtual CPU devices (sets XLA_FLAGS; "
                         "must win the race with backend init)")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-tiles", type=int, default=64)
    ap.add_argument("--fuse-rows", type=int, default=256)
    ap.add_argument("--warmup", type=int, default=1,
                    help="unmeasured epochs of the full workload before the "
                         "measured one (compiles all steady-state shapes)")
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--plan-cache", default=None)
    ap.add_argument("--json", dest="out_json", default=None)
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--log-level", default="warning",
                    help="repro.* logger verbosity (obs/logging)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto span trace of the whole "
                         "run (warmup included) with per-request async "
                         "tracks keyed by ticket id")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="expose the serve-mode service's Prometheus "
                         "/metrics on 127.0.0.1:PORT (0 = ephemeral)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="scrape the service's /metrics once after the "
                         "measured epoch and write the exposition text to "
                         "PATH (implies an ephemeral --metrics-port)")
    ap.add_argument("--assert-goodput-x", type=float, default=None,
                    help="require serve goodput >= X * serial goodput at "
                         "p99 <= --p99-tol * serial p99 (needs --mode both)")
    ap.add_argument("--p99-tol", type=float, default=1.1)
    args = ap.parse_args(argv)
    if args.virtual_devices:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.virtual_devices}")

    from repro.launch.clique import load_graph, parse_devices
    from repro.obs import trace
    from repro.obs.export import scrape
    from repro.obs.logging import setup_logging
    from repro.resilience import inject
    from repro.serve import CliqueService

    setup_logging(args.log_level)
    if args.trace_out:
        trace.configure(enabled=True)
    if args.metrics_out is not None and args.metrics_port is None:
        args.metrics_port = 0  # snapshot needs a live endpoint to scrape

    # graph specs may contain commas (er:300,0.08): a comma only starts a
    # new spec when the next fragment has its own "name:" prefix
    graphs: list = []
    for part in args.graphs.split(","):
        if graphs and ":" not in part:
            graphs[-1] += "," + part
        else:
            graphs.append(part)
    ks = [int(x) for x in args.ks.split(",")]
    devices = parse_devices(args.devices)
    graph_objs = {name: load_graph(name) for name in graphs}

    if args.loop == "closed":
        n_requests = args.clients * args.requests_per_client
    else:
        n_requests = max(1, int(args.duration * max(
            float(r) for r in args.rates.split(","))))
    workload = build_workload(graphs, ks, n_requests, args.list_frac,
                              args.filter_frac, args.max_out,
                              args.deadline_ms, args.seed)
    config = {k: v for k, v in vars(args).items() if k != "out_json"}
    print(f"# workload: {len(workload)} requests over {graphs} ks={ks}",
          flush=True)
    print("# building oracle (plain engines)...", flush=True)
    oracle, solo = build_oracle(graph_objs, workload, args.backend)
    if args.deadline_x:
        # proportional SLOs: a heavy request gets a proportionally longer
        # deadline, so goodput measures scheduling (head-of-line blocking
        # vs EDF interleaving), not just raw speed
        for spec in workload:
            base = solo[(spec["graph"], spec["k"], spec["mode"])]
            spec["deadline_s"] = max(args.deadline_x * base, 2e-3)
        slos = sorted(set(round(s["deadline_s"] * 1e3, 1)
                          for s in workload))
        print(f"# proportional SLOs ({args.deadline_x}x solo): "
              f"{slos[0]}..{slos[-1]}ms", flush=True)

    def serve_factory():
        svc = CliqueService(
            devices=devices, backend=args.backend,
            chunk_tiles=args.chunk_tiles, fuse_rows=args.fuse_rows,
            max_pending=args.max_pending, plan_cache_dir=args.plan_cache,
            metrics_port=args.metrics_port)
        if svc.metrics_address:
            print(f"# metrics: {svc.metrics_address}/metrics", flush=True)
        for name, g in graph_objs.items():
            svc.register_graph(name, g)
        return (lambda spec, block=True: _submit_serve(svc, spec, block),
                svc.close, svc)

    def snapshot_metrics(svc):
        # one scrape while the service (and its collector) is still alive
        if svc is None or args.metrics_out is None:
            return
        if svc.metrics_address is None:
            return
        text = scrape(svc.metrics_address)
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"# wrote {args.metrics_out} "
              f"({len(text.splitlines())} exposition lines)", flush=True)

    def serial_factory():
        ex = SerialExecutor(graph_objs, devices, args.backend,
                            max_pending=args.max_pending)
        return ex.submit, ex.close, None

    def _wait(ticket, timeout=600.0):
        # serve Tickets expose result(); SerialExecutor tickets expose get()
        if hasattr(ticket, "get"):
            return ticket.get(timeout)
        return ticket.result(timeout)

    def finish_record(rec, mode, svc):
        rec.update(kind="serve_loadgen", graph="+".join(graphs), ks=ks,
                   devices=args.devices, backend=args.backend, config=config)
        if svc is not None:
            s = svc.stats
            es = svc.engine_stats
            rec["serve_stats"] = {
                "fused_batches": s.fused_batches,
                "cross_request_batches": s.cross_request_batches,
                "fused_rows": s.fused_rows,
                "fused_chunks": s.fused_chunks,
                "deadline_flushes": s.deadline_flushes,
                "rejected": s.rejected,
                # resilience counters (nonzero under --fault-rate chaos)
                "retries": es.retries,
                "demotions": es.demotions,
                "isolated_failures": s.isolated_failures,
                "deadline_cancels": s.deadline_cancels,
                "shed": s.shed,
                "faults_injected": inject.fired(),
            }
        print(f"# {mode}/{rec['loop']}: {rec['completed']} ok, "
              f"{rec['mismatches']} mismatches, "
              f"p50={rec['p50_ms']:.1f}ms p99={rec['p99_ms']:.1f}ms "
              f"goodput={rec['goodput_rps']:.2f}/s "
              f"miss_rate={rec['miss_rate']:.2f}", flush=True)
        records.append(rec)
        return rec["mismatches"]

    modes = [args.mode] if args.mode != "both" else ["serve", "serial"]
    records = []
    failures = 0
    for mode in modes:
        factory = serve_factory if mode == "serve" else serial_factory
        # chaos mode: seeded injection is scoped to the serve run only --
        # the oracle (already built) and the serial baseline stay clean,
        # so any mismatch is a real resilience bug, not a noisy reference
        chaos = args.fault_rate > 0 and mode == "serve"
        if chaos:
            inject.configure(
                f"seed={args.fault_seed};*={args.fault_rate};"
                f"kernel.launch={max(args.fault_rate, 0.1)}")
            print(f"# chaos: injecting faults at rate {args.fault_rate} "
                  f"(seed {args.fault_seed})", flush=True)
        if args.loop == "closed":
            submit, close, svc = factory()
            # unmeasured epochs of the identical concurrent workload: warm
            # plans and every steady-state executable shape (including the
            # partial-flush pow2 buckets only concurrency produces) so the
            # measured epoch is the steady serving state
            for _ in range(args.warmup):
                run_closed(submit, workload, args.clients, oracle)
            lat, missed, mism, wall, stages = run_closed(
                submit, workload, args.clients, oracle)
            snapshot_metrics(svc)
            close()
            rec = summarize(mode, lat, missed, mism, 0, wall)
            rec.update(loop="closed", clients=args.clients,
                       stage_breakdown=stage_breakdown(stages))
            failures += finish_record(rec, mode, svc)
        else:
            for rate in (float(r) for r in args.rates.split(",")):
                submit, close, svc = factory()
                for _ in range(args.warmup):
                    run_closed(submit, workload, max(4, args.clients), oracle)
                lat, missed, mism, rejected, wall, stages = run_open(
                    submit, workload, rate, oracle, args.seed)
                snapshot_metrics(svc)
                close()
                rec = summarize(mode, lat, missed, mism, rejected, wall)
                rec.update(loop="open", rate=rate,
                           stage_breakdown=stage_breakdown(stages))
                failures += finish_record(rec, mode, svc)
        if chaos:
            inject.configure(None)

    if args.trace_out:
        trace.export(args.trace_out)
        print(f"# wrote {args.trace_out} ({len(trace.events())} trace "
              f"events, {trace.dropped()} dropped)", flush=True)

    if args.out_json:
        payload = {"graph": "+".join(graphs), "ks": ks,
                   "devices": args.devices,
                   "backends": [args.backend or "auto"],
                   "records": records}
        if args.out_json == "-":
            json.dump(payload, sys.stdout, indent=1)
            print(flush=True)
        else:
            if args.append and os.path.exists(args.out_json):
                with open(args.out_json) as f:
                    prior = json.load(f)
                payload["records"] = prior.get("records", []) + records
            with open(args.out_json, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {args.out_json} "
                  f"({len(payload['records'])} records)", flush=True)

    if failures:
        print(f"# FAIL: {failures} oracle mismatches", flush=True)
        return 1
    if args.assert_goodput_x is not None:
        serve = [r for r in records if r["mode"] == "serve"]
        serial = [r for r in records if r["mode"] == "serial"]
        if not serve or not serial:
            print("# FAIL: --assert-goodput-x needs --mode both", flush=True)
            return 1
        gx = serve[0]["goodput_rps"] / max(serial[0]["goodput_rps"], 1e-9)
        p99_ok = serve[0]["p99_ms"] <= serial[0]["p99_ms"] * args.p99_tol
        print(f"# goodput serve/serial = {gx:.2f}x "
              f"(p99 {serve[0]['p99_ms']:.1f}ms vs "
              f"{serial[0]['p99_ms']:.1f}ms)", flush=True)
        if gx < args.assert_goodput_x or not p99_ok:
            print(f"# FAIL: goodput ratio {gx:.2f} < "
                  f"{args.assert_goodput_x} or p99 regressed", flush=True)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
