"""Randomized-delay determinism stress for the emission pipeline.

The ListDispatcher promotes pending batches when their emit-sizing count
pass "lands", probed non-blockingly via ``dispatch._is_ready``.  Real
device timing is nondeterministic, so these tests *force* the adversarial
schedules by monkeypatching the probe: always-cold (nothing ever looks
ready -- promotion only happens under backpressure or at drain), seeded
random flakiness, and always-hot.  Under every schedule, combined with
every pack-worker count, prefetch depth, staging mode, and the local
device count, the sink output must stay **byte-identical in batch order**
to the serial single-device reference.
"""
import jax
import numpy as np

from repro.core import ebbkc, listing, pipeline
from repro.data import rmat_graph
from repro.runtime import dispatch as dsp

N_DEV = jax.device_count()

_REAL_IS_READY = dsp._is_ready


def _flaky_probe(seed: int):
    rnd = np.random.default_rng(seed)
    return lambda x: bool(rnd.random() < 0.5) and _REAL_IS_READY(x)


def _graph():
    return rmat_graph(8, 4, seed=7)


def _run(g, k, **kwargs):
    sink = listing.ArraySink(k)
    res = listing.stream_cliques(g, k, sink, **kwargs)
    return sink.result(), res.stats


def test_harvest_determinism_under_randomized_delays(monkeypatch):
    """Sweep readiness schedules x worker counts x prefetch depths x
    staging x device counts: identical arrays, not just identical sets."""
    g = _graph()
    k = 4
    base, base_stats = _run(g, k, devices=1, pack_workers=0, batch_size=32)
    assert base.shape[0] == ebbkc.count(g, k).count
    probes = [("cold", lambda x: False), ("hot", lambda x: True),
              ("flaky3", _flaky_probe(3)), ("flaky11", _flaky_probe(11))]
    configs = [
        dict(devices=N_DEV, pack_workers=0),
        dict(devices=N_DEV, pack_workers=2, prefetch=1),
        dict(devices=N_DEV, pack_workers=3, prefetch=8),
        dict(devices=N_DEV, pack_workers=2, async_staging=False),
        dict(devices=1, pack_workers=4, max_inflight=1),
        # explicit exact sizing (alias of the default): the _is_ready
        # probe gates promotion
        dict(devices=N_DEV, pack_workers=2, capacity="sized"),
        # speculative ratchet + retry path
        dict(devices=N_DEV, pack_workers=2, capacity="speculative",
             max_inflight=1),
    ]
    for pname, probe in probes:
        monkeypatch.setattr(dsp, "_is_ready", probe)
        for cfg in configs:
            got, stats = _run(g, k, batch_size=32, **cfg)
            assert np.array_equal(got, base), (pname, cfg)
            assert stats.emitted_cliques == base_stats.emitted_cliques


def test_determinism_under_overflow_and_fixed_capacity(monkeypatch):
    """The overflow -> host re-list path must splice rows back in batch
    order even when promotion timing is adversarial."""
    g = _graph()
    k = 4
    base, _ = _run(g, k, devices=1, pack_workers=0, batch_size=16)
    monkeypatch.setattr(dsp, "_is_ready", _flaky_probe(5))
    for cap in (2, 8):  # tiny fixed capacities force overflow re-lists
        got, stats = _run(g, k, devices=N_DEV, pack_workers=2,
                          batch_size=16, capacity=cap)
        assert np.array_equal(got, base), cap
    monkeypatch.setattr(dsp, "_is_ready", lambda x: False)
    got, stats = _run(g, k, devices=N_DEV, pack_workers=3, batch_size=16)
    assert np.array_equal(got, base)


def test_speculative_capacity_retries_are_invisible(monkeypatch):
    """A deliberately tiny initial capacity guess forces device retries;
    the output must stay byte-identical and the retries accounted."""
    g = _graph()
    k = 4
    base, _ = _run(g, k, devices=1, pack_workers=0, batch_size=16)
    monkeypatch.setattr(dsp, "SPECULATIVE_CAP0", 1)
    got, stats = _run(g, k, devices=N_DEV, pack_workers=2, batch_size=16,
                      capacity="speculative")
    assert np.array_equal(got, base)
    assert stats.emit_retries > 0
    assert stats.overflowed_tiles == 0  # retried on device, not the host
    # the ratchet makes later batches of the same width right-sized, so
    # retries stay far below the batch count
    n_batches = sum(1 for b in pipeline.stream_batches(g, k, batch_size=16)
                    if isinstance(b, pipeline.TileBatch))
    assert stats.emit_retries < n_batches


def test_parallel_producer_is_order_deterministic():
    """stream_batches yields the identical batch sequence for every
    worker count / prefetch depth (the determinism contract the sink
    ordering builds on)."""
    g = _graph()
    ref = [b for b in pipeline.stream_batches(g, 5, batch_size=16)]
    for workers, depth in ((1, 1), (2, 2), (3, 8), (4, None)):
        got = [b for b in pipeline.stream_batches(
            g, 5, batch_size=16, pack_workers=workers, prefetch=depth)]
        assert len(got) == len(ref), (workers, depth)
        for a, b in zip(ref, got):
            assert type(a) is type(b)
            if isinstance(a, pipeline.TileBatch):
                for f in ("A", "cand", "sizes", "nedges", "anchors",
                          "verts"):
                    assert np.array_equal(getattr(a, f), getattr(b, f)), \
                        (workers, depth, f)


def test_capacity_aliases_work_on_every_path():
    """The string capacity modes must not crash the single-device path
    (they fall back to exact sizing there), and speculative mode must
    honor max_capacity."""
    g = _graph()
    k = 4
    base, _ = _run(g, k, devices=1, pack_workers=0, batch_size=32)
    for cap in ("sized", "speculative"):
        for dev in (None, 1, N_DEV):
            got, _ = _run(g, k, devices=dev, batch_size=32, capacity=cap)
            assert np.array_equal(got, base), (cap, dev)
    import pytest

    with pytest.raises(ValueError, match="capacity"):
        _run(g, k, devices=1, capacity="bogus")
    # max_capacity below the initial guess: the guess must clamp, and
    # over-capacity tiles re-list on the host exactly as in every mode
    got, stats = _run(g, k, devices=N_DEV, batch_size=32,
                      capacity="speculative", max_capacity=4)
    assert np.array_equal(got, base)


def test_early_close_shuts_down_producer():
    """Abandoning a parallel stream (sink.full / consumer break) must not
    leak or deadlock the worker pool."""
    g = _graph()
    stream = pipeline.stream_batches(g, 4, batch_size=8, pack_workers=2)
    first = next(stream)
    assert first is not None
    stream.close()  # must return promptly, cancelling queued work
    # a bounded sink stops the producer the same way through the engine
    sink = listing.ArraySink(4, max_out=5)
    listing.stream_cliques(g, 4, sink, devices=N_DEV, pack_workers=2)
    assert sink.accepted == 5
