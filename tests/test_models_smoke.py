"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.steps import build_cell

SMOKE_CELLS = [
    ("deepseek-moe-16b", "train_4k"),
    ("deepseek-moe-16b", "decode_32k"),
    ("dbrx-132b", "train_4k"),
    ("dbrx-132b", "prefill_32k"),
    ("gemma3-27b", "train_4k"),
    ("gemma3-27b", "long_500k"),
    ("nemotron-4-15b", "train_4k"),
    ("nemotron-4-15b", "decode_32k"),
    ("granite-3-8b", "train_4k"),
    ("granite-3-8b", "prefill_32k"),
    ("gin-tu", "full_graph_sm"),
    ("gin-tu", "molecule"),
    ("nequip", "molecule"),
    ("nequip", "minibatch_lg"),
    ("meshgraphnet", "full_graph_sm"),
    ("meshgraphnet", "molecule"),
    ("egnn", "molecule"),
    ("egnn", "ogb_products"),
    ("dcn-v2", "train_batch"),
    ("dcn-v2", "serve_p99"),
    ("dcn-v2", "retrieval_cand"),
    ("ebbkc", "ep_tri_1m"),
]


def materialize(x, key=jax.random.PRNGKey(0)):
    if not isinstance(x, jax.ShapeDtypeStruct):
        return x
    if jnp.issubdtype(x.dtype, jnp.integer):
        return jax.random.randint(key, x.shape, 0, 2).astype(x.dtype)
    # abs: second-moment (nu) optimizer slots must be non-negative
    return jnp.abs(jax.random.normal(key, x.shape) * 0.02).astype(x.dtype)


@pytest.mark.parametrize("arch,shape", SMOKE_CELLS,
                         ids=[f"{a}-{s}" for a, s in SMOKE_CELLS])
def test_arch_smoke(arch, shape):
    spec = configs.get(arch)
    cell = build_cell(spec, shape, mesh=None, reduced=True)
    args = jax.tree.map(materialize, cell.abstract_args,
                        is_leaf=lambda y: isinstance(y, jax.ShapeDtypeStruct))
    out = jax.jit(cell.step_fn)(*args)
    # shapes match the declared abstract output where available; always: no NaN
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), (arch, shape)


def test_all_assigned_archs_registered():
    assert len(configs.ASSIGNED) == 10
    for name in configs.ASSIGNED:
        spec = configs.get(name)
        assert len(spec.cells) == 4, name


def test_lm_train_loss_decreases():
    """The training substrate actually learns (tiny LM, 30 steps)."""
    from repro.models import transformer as tr
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.data import LMDataPipeline

    cfg = configs.get("granite-3-8b").reduced
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    pipe = LMDataPipeline(vocab=cfg.vocab, batch=4, seq_len=32)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: tr.loss_fn(p, batch, cfg))(params)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, loss

    losses = []
    for _ in range(30):
        b = pipe.next_batch()
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
