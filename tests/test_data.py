"""Data pipelines: determinism, resume, sampler shapes."""
import numpy as np

from repro.data import (GraphBatcher, LMDataPipeline, NeighborSampler,
                        RecsysPipeline, erdos_renyi, planted_cliques,
                        powerlaw_graph, rmat_graph)


def test_lm_pipeline_deterministic_resume():
    p1 = LMDataPipeline(vocab=100, batch=2, seq_len=8, seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.state()
    later = [p1.next_batch() for _ in range(3)]
    p2 = LMDataPipeline(vocab=100, batch=2, seq_len=8, seed=3)
    p2.restore(state)
    replay = [p2.next_batch() for _ in range(3)]
    for a, b in zip(later, replay):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards differ
    p3 = LMDataPipeline(vocab=100, batch=2, seq_len=8, seed=3, shard_id=1)
    assert not np.array_equal(p3.next_batch()["tokens"],
                              batches[0]["tokens"])


def test_recsys_pipeline_labels_learnable():
    p = RecsysPipeline(batch=512, vocab=50, seed=1)
    b = p.next_batch()
    assert b["dense"].shape == (512, 13)
    assert b["sparse"].shape == (512, 26, 1)
    assert 0.05 < b["labels"].mean() < 0.95


def test_graph_batcher_resume():
    g1 = GraphBatcher(batch=4, seed=5)
    _ = g1.next_batch()
    st = g1.state()
    nxt = g1.next_batch()
    g2 = GraphBatcher(batch=4, seed=5)
    g2.restore(st)
    np.testing.assert_array_equal(nxt["nodes"], g2.next_batch()["nodes"])


def test_generators_produce_simple_graphs():
    for g in (erdos_renyi(50, 0.2, 1), powerlaw_graph(100, 4, 1),
              rmat_graph(7, 4, 1), planted_cliques(80, 4, 6, seed=1)):
        assert g.m > 0
        assert (g.edges[:, 0] < g.edges[:, 1]).all()  # canonical, no loops
        keys = g.edges[:, 0] * g.n + g.edges[:, 1]
        assert len(np.unique(keys)) == g.m            # no duplicates


def test_planted_cliques_found():
    from repro.core import ebbkc
    g = planted_cliques(200, 3, 8, p_noise=0.0, seed=2)
    # each planted 8-clique contributes C(8,5) 5-cliques (may overlap)
    r = ebbkc.count(g, 5)
    assert r.count >= 3 * 56 - 100


def test_neighbor_sampler():
    g = erdos_renyi(200, 0.1, seed=3)
    s = NeighborSampler(g, batch_nodes=16, fanouts=(5, 3), seed=1)
    b = s.next_batch()
    assert b["seeds"].shape == (16,)
    assert b["blocks"][0]["nbrs"].shape == (16, 5)
    assert b["blocks"][1]["nbrs"].shape == (80, 3)
    # determinism
    s2 = NeighborSampler(g, batch_nodes=16, fanouts=(5, 3), seed=1)
    np.testing.assert_array_equal(b["seeds"], s2.next_batch()["seeds"])
