"""repro.delta: incremental plan maintenance for dynamic graphs.

Covers the mutable-graph seam (``apply_edge_batch``), localized truss
repair and tile-table splicing (array-identical to a from-scratch build
under the repaired decomposition), the churn-threshold rebuild fallback
(recorded in Stats), per-batch and composed clique deltas, the versioned
:class:`~repro.delta.PlanIndex` with persisted lineage, and the serving
tier's ``update_graph`` / ``mode="delta"`` subscription path.

The committed regression at the bottom pins the touched-set closure bug
found while building this layer: two deleted edges sharing a common
neighborhood put a survivor's edge in ``touched_old`` only, so its tile
was retired without a replacement and one triangle silently vanished.
"""
import numpy as np
import pytest

from repro.core import ebbkc, pipeline
from repro.core.engine_np import Stats
from repro.core.graph import Graph, apply_edge_batch, from_edges
from repro.core.truss import edge_subset_supports, edge_supports
from repro.data import rmat_graph
from repro.delta import (CHURN_THRESHOLD, PlanIndex, delta_cliques,
                         repair_plan)
from repro.delta.query import rows_diff, rows_sorted, rows_union


def rand_graph(n: int, m: int, seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    return from_edges(n, rng.integers(0, n, size=(m, 2)))


def mutate(g: Graph, seed: int, n_ins: int = 4, n_del: int = 3):
    """One random batch: fresh pairs in, a sample of current edges out."""
    rng = np.random.default_rng(seed)
    ins = rng.integers(0, g.n, (n_ins, 2)) if n_ins else None
    dele = g.edges[rng.choice(g.m, min(g.m, n_del), replace=False)] \
        if n_del and g.m else None
    return apply_edge_batch(g, insert=ins, delete=dele)


# -- apply_edge_batch (the mutable-graph seam) ------------------------------

def test_apply_edge_batch_semantics():
    g = from_edges(6, np.array([[0, 1], [1, 2], [0, 2], [3, 4]]))
    # insert dedups/canonicalizes; self loops dropped; n preserved
    g2 = apply_edge_batch(g, insert=[(2, 0), (4, 3), (2, 3), (5, 5)])
    assert g2.n == g.n and g2.m == g.m + 1
    # delete is exact; deleting an absent edge is a no-op
    g3 = apply_edge_batch(g2, delete=[(3, 2), (0, 5)])
    assert np.array_equal(g3.edges, g.edges)
    # insert wins when a pair appears in both lists (delete-then-insert)
    g4 = apply_edge_batch(g, insert=[(0, 1)], delete=[(1, 0)])
    assert np.array_equal(g4.edges, g.edges)
    # idempotent
    g5 = apply_edge_batch(g4, insert=[(0, 1)])
    assert np.array_equal(g5.edges, g4.edges)
    # validation: endpoints must be inside [0, n)
    with pytest.raises(ValueError):
        apply_edge_batch(g, insert=[(0, 6)])
    with pytest.raises(ValueError):
        apply_edge_batch(g, delete=[(-1, 0)])
    # empty batch is the identity
    assert np.array_equal(apply_edge_batch(g).edges, g.edges)


def test_edge_subset_supports_matches_full():
    for seed in range(4):
        g = rand_graph(20, 70, seed)
        full = edge_supports(g)
        eids = np.sort(np.random.default_rng(seed).choice(
            g.m, size=g.m // 2, replace=False))
        assert np.array_equal(edge_subset_supports(g, eids), full[eids])
        assert np.array_equal(
            edge_subset_supports(g, np.arange(g.m)), full)


# -- repair_plan: equivalence, fallback, accounting -------------------------

@pytest.mark.parametrize("order", ["truss", "hybrid"])
def test_repair_matches_from_scratch(order):
    stats = Stats()
    for seed in range(5):
        g = rand_graph(22, 80, seed)
        plan = pipeline.build_plan(g, order)
        g2 = mutate(g, seed + 50)
        plan2, info = repair_plan(plan, g2, order, churn_threshold=1.1,
                                  stats=stats)
        assert not info.rebuilt and stats.plan_repairs == seed + 1
        assert stats.plan_repair_s > 0
        scratch = pipeline.build_plan(g2, order)
        for k in (3, 4, 5):
            assert ebbkc.count(g2, k, plan=plan2).count == \
                ebbkc.count(g2, k, plan=scratch).count, (seed, k)
            a, _ = ebbkc.list_cliques(g2, k, order=order, plan=plan2)
            b, _ = ebbkc.list_cliques(g2, k, order=order, plan=scratch)
            assert np.array_equal(rows_sorted(a), rows_sorted(b)), (seed, k)
    # across the sweep, at least one batch touched a real neighborhood
    assert stats.delta_touched_edges > 0


def test_splice_is_array_identical_to_full_build():
    """The spliced table must equal a full build under the repaired
    decomposition field-for-field -- the splice is bookkeeping only."""
    for seed in range(5):
        g = rand_graph(24, 90, seed)
        plan = pipeline.build_plan(g, "truss")
        g2 = mutate(g, seed + 9)
        plan2, info = repair_plan(plan, g2, "truss", churn_threshold=1.1)
        assert not info.rebuilt
        full = pipeline._build_truss_table(g2, plan2._td)
        tab = plan2._tables["truss"]
        for f in ("edge_id", "anchors", "offsets", "verts", "thresh",
                  "ekeys", "erank"):
            assert np.array_equal(getattr(tab, f), getattr(full, f)), \
                (seed, f)


def test_churn_threshold_falls_back_to_rebuild():
    g = rand_graph(20, 60, 1)
    plan = pipeline.build_plan(g, "hybrid")
    g2 = apply_edge_batch(
        g, insert=np.random.default_rng(99).integers(0, 20, (40, 2)))
    stats = Stats()
    plan2, info = repair_plan(plan, g2, "hybrid", churn_threshold=0.05,
                              stats=stats)
    assert info.rebuilt and info.churn > 0.05
    assert stats.plan_rebuilds == 1 and stats.plan_build_s > 0
    assert stats.plan_repairs == 0
    assert ebbkc.count(g2, 4, plan=plan2).count == ebbkc.count(g2, 4).count
    # the default threshold is sane and the color family always rebuilds
    assert 0 < CHURN_THRESHOLD < 1
    cplan = pipeline.build_plan(g, "color")
    _, cinfo = repair_plan(cplan, g2, "color", churn_threshold=1.1)
    assert cinfo.rebuilt


def test_repair_stats_merge_tripwire():
    """New Stats fields must be merge-registered (the _MERGE_KINDS
    tripwire) so multi-worker accounting folds instead of raising."""
    a, b = Stats(), Stats()
    a.plan_repairs, a.plan_rebuilds = 2, 1
    a.plan_repair_s, a.delta_touched_edges = 0.5, 40
    b.plan_repairs, b.delta_touched_edges = 1, 2
    a.merge(b)
    assert (a.plan_repairs, a.plan_rebuilds, a.delta_touched_edges) == \
        (3, 1, 42)
    assert a.plan_repair_s == 0.5


def test_repair_rejects_vertex_set_change():
    g = rand_graph(10, 20, 0)
    plan = pipeline.build_plan(g, "hybrid")
    bigger = from_edges(12, g.edges)
    with pytest.raises(ValueError):
        repair_plan(plan, bigger, "hybrid")


# -- clique deltas ----------------------------------------------------------

def test_delta_cliques_exact_per_batch():
    for seed in range(4):
        g = rand_graph(20, 75, seed)
        plan = pipeline.build_plan(g, "hybrid")
        g2 = mutate(g, seed + 31)
        plan2, info = repair_plan(plan, g2, "hybrid", churn_threshold=1.1)
        for k in (3, 4):
            d = delta_cliques(plan, plan2, info, k)
            a, _ = ebbkc.list_cliques(g, k)
            b, _ = ebbkc.list_cliques(g2, k)
            a, b = rows_sorted(a), rows_sorted(b)
            assert np.array_equal(d.gained, rows_sorted(rows_diff(b, a)))
            assert np.array_equal(d.lost, rows_sorted(rows_diff(a, b)))
            assert d.net == b.shape[0] - a.shape[0]
    with pytest.raises(ValueError):
        delta_cliques(plan, plan2, info, 2)


def test_rows_set_algebra():
    a = np.array([[0, 1, 2], [1, 2, 3], [2, 3, 4]], dtype=np.int64)
    b = np.array([[1, 2, 3], [5, 6, 7]], dtype=np.int64)
    assert np.array_equal(rows_diff(a, b), a[[0, 2]])
    assert rows_union(a, b).shape[0] == 4
    empty = np.zeros((0, 3), dtype=np.int64)
    assert np.array_equal(rows_diff(a, empty), a)
    assert rows_diff(empty, a).shape == (0, 3)
    assert np.array_equal(rows_union(empty, b), rows_sorted(b))


# -- PlanIndex: versioning, composition, lineage ----------------------------

def test_plan_index_versions_and_composed_deltas():
    g = rand_graph(24, 85, 7)
    idx = PlanIndex(g, "hybrid", churn_threshold=1.1, history=8)
    assert idx.version == 0 and idx.oldest_version() == 0
    snaps = {0: g}
    for b in range(5):
        v = idx.apply_batch(
            insert=np.random.default_rng(200 + b).integers(0, 24, (3, 2)),
            delete=idx.graph.edges[
                np.random.default_rng(300 + b).choice(
                    idx.graph.m, 2, replace=False)])
        assert v == b + 1
        snaps[v] = idx.graph
    # warm queries after mutation: the repaired plan is the cached plan
    s = Stats()
    assert pipeline.cached_plan(idx.graph, "hybrid", stats=s) is idx.plan
    assert s.plan_cache_hit
    # composed deltas equal from-scratch snapshot diffs for every base
    for since in range(6):
        for k in (3, 4):
            d = idx.delta(k, since)
            a, _ = ebbkc.list_cliques(snaps[since], k)
            b_, _ = ebbkc.list_cliques(idx.graph, k)
            a, b_ = rows_sorted(a), rows_sorted(b_)
            assert np.array_equal(d.gained, rows_sorted(rows_diff(b_, a)))
            assert np.array_equal(d.lost, rows_sorted(rows_diff(a, b_)))
    # the subscription read composes the vertex filter
    full = idx.delta(3, 0).gained
    if full.shape[0]:
        v = int(full[0, 0])
        got = idx.gained_since(3, 0, vertex=v)
        assert np.array_equal(got, full[(full == v).any(axis=1)])
    # range validation
    with pytest.raises(ValueError):
        idx.delta(3, idx.version + 1)
    with pytest.raises(ValueError):
        idx.delta(3, -1)


def test_plan_index_history_window():
    g = rand_graph(16, 40, 3)
    idx = PlanIndex(g, "hybrid", churn_threshold=1.1, history=2)
    for b in range(4):
        idx.apply_batch(
            insert=np.random.default_rng(b).integers(0, 16, (2, 2)))
    assert idx.version == 4 and idx.oldest_version() == 2
    idx.delta(3, 2)  # inside the window
    with pytest.raises(ValueError):
        idx.delta(3, 1)  # history exhausted


def test_plan_index_lineage_persisted(tmp_path):
    from repro.checkpoint import store

    pipeline.clear_plan_cache()
    g = rand_graph(18, 55, 11)
    cache = str(tmp_path / "plans")
    idx = PlanIndex(g, "hybrid", churn_threshold=1.1, cache_dir=cache)
    parent = idx.plan_key
    idx.apply_batch(insert=np.random.default_rng(1).integers(0, 18, (3, 2)))
    meta = store.read_metadata(
        str(tmp_path / "plans" / idx.plan_key))
    assert meta is not None
    lin = meta["lineage"]
    assert lin["version"] == 1 and lin["parent_key"] == parent
    assert lin["repaired"] is True and lin["inserted"] >= 1
    # the persisted repaired plan restores across "processes" and is exact
    pipeline.clear_plan_cache()
    s = Stats()
    plan = pipeline.cached_plan(idx.graph, "hybrid", cache_dir=cache,
                                stats=s)
    assert s.plan_cache_hit
    assert ebbkc.count(idx.graph, 4, plan=plan).count == \
        ebbkc.count(idx.graph, 4).count
    assert store.read_metadata(str(tmp_path / "absent")) is None


# -- serving tier: update_graph + delta subscriptions -----------------------

def test_service_update_graph_and_delta_subscription():
    from repro.serve import CliqueService

    rng = np.random.default_rng(5)
    n = 30
    g = from_edges(n, rng.integers(0, n, (140, 2)))
    svc = CliqueService()
    try:
        svc.register_graph("g", g)
        assert svc.graph_version("g") == 0
        # empty delta at the current version
        d0 = svc.submit("g", 3, "delta", since_version=0).result(timeout=120)
        assert d0.rows.shape == (0, 3) and d0.kind == "delta"
        v1 = svc.update_graph("g", insert=rng.integers(0, n, (12, 2)))
        assert v1 == 1 and svc.stats.graph_updates == 1
        g2 = svc._entry("g").graph
        # post-mutation queries serve the mutated snapshot exactly
        assert svc.submit("g", 4, "count").result(timeout=120).count == \
            ebbkc.count(g2, 4).count
        # subscription read == from-scratch snapshot diff
        a, _ = ebbkc.list_cliques(g, 3)
        b, _ = ebbkc.list_cliques(g2, 3)
        gain = rows_sorted(rows_diff(rows_sorted(b), rows_sorted(a)))
        d = svc.submit("g", 3, "delta", since_version=0).result(timeout=120)
        assert np.array_equal(rows_sorted(d.rows), gain)
        assert d.emitted == d.rows.shape[0] and gain.shape[0] > 0
        # vertex_filter and max_out compose exactly as in listing mode
        v = int(gain[0, 0])
        dv = svc.submit("g", 3, "delta", since_version=0,
                        vertex_filter=v).result(timeout=120)
        assert np.array_equal(
            rows_sorted(dv.rows),
            rows_sorted(gain[(gain == v).any(axis=1)]))
        dm = svc.submit("g", 3, "delta", since_version=0,
                        max_out=2).result(timeout=120)
        assert dm.rows.shape[0] == min(2, gain.shape[0])
        assert svc.stats.delta_requests >= 4
        # error paths resolve the ticket; the service keeps serving
        with pytest.raises(ValueError):
            svc.submit("g", 3, "delta",
                       since_version=99).result(timeout=120)
        with pytest.raises(ValueError):  # delta needs a registered name
            svc.submit(g2, 3, "delta", since_version=0)
        with pytest.raises(ValueError):  # delta needs since_version
            svc.submit("g", 3, "delta")
        with pytest.raises(ValueError):  # and k >= 3
            svc.submit("g", 2, "delta", since_version=0)
        assert svc.submit("g", 3, "count").result(timeout=120).count == \
            ebbkc.count(g2, 3).count
    finally:
        svc.close()


def test_service_update_unknown_graph_raises():
    from repro.serve import CliqueService

    svc = CliqueService(start=False)
    with pytest.raises(KeyError):
        svc.update_graph("nope", insert=[(0, 1)])
    svc.close()


# -- committed regression: touched-set closure over survivors ---------------

def test_regression_touched_set_closure():
    """Two deleted edges sharing a common neighborhood used to leave a
    surviving edge's tile retired with no replacement (it sat in
    ``touched_old`` only), silently dropping one triangle.  Found by the
    rng(5)/n=30 two-batch sequence below; the fix closes the touched
    sets symmetrically over surviving edges."""
    rng = np.random.default_rng(5)
    n = 30
    g = from_edges(n, rng.integers(0, n, (140, 2)))
    idx = PlanIndex(g, "hybrid", churn_threshold=1.1)
    idx.apply_batch(insert=rng.integers(0, n, (4, 2)))
    idx.apply_batch(delete=idx.graph.edges[:3])
    for k in (3, 4, 5):
        assert ebbkc.count(idx.graph, k, plan=idx.plan).count == \
            ebbkc.count(idx.graph, k).count, k
