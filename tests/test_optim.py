"""Optimizer + compression unit tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule,
                         linear_schedule, int8_compress, int8_decompress,
                         compressed_allreduce, compressed_psum_tree)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, clip_norm=None)
    opt = adamw_init(params)
    for _ in range(200):
        g = jax.tree.map(lambda p: 2 * p, params)  # grad of ||p||^2
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == 20.0


def test_schedules():
    cos = cosine_schedule(10, 100)
    lin = linear_schedule(10, 100)
    assert float(cos(jnp.int32(0))) == 0.0
    assert abs(float(cos(jnp.int32(10))) - 1.0) < 1e-6
    assert float(cos(jnp.int32(100))) <= 0.11
    assert abs(float(lin(jnp.int32(100)))) < 1e-6


def test_int8_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(257,)), jnp.float32)
    err = jnp.zeros_like(x)
    # single round trip: bounded error
    q, s, err1 = int8_compress(x, err)
    y = int8_decompress(q, s)
    assert float(jnp.abs(y - x).max()) <= float(s) * 0.51 + 1e-6
    # error feedback: accumulated mean over repeats converges to x
    acc = jnp.zeros_like(x)
    err = jnp.zeros_like(x)
    n = 50
    for _ in range(n):
        q, s, err = int8_compress(x, err)
        acc = acc + int8_decompress(q, s)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(x),
                               atol=1e-3)


def test_compressed_allreduce_no_axis():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(33,)), jnp.float32)
    err = jnp.zeros_like(x)
    y, new_err = compressed_allreduce(x, err, None)
    np.testing.assert_allclose(np.asarray(y + new_err), np.asarray(x),
                               atol=1e-5)


def test_compressed_psum_tree_structure():
    tree = {"a": jnp.ones((5,)), "b": [jnp.zeros((3, 3))]}
    err = jax.tree.map(jnp.zeros_like, tree)
    out, err2 = compressed_psum_tree(tree, err, None)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
