"""EBBkC correctness: all orderings x ET settings vs brute force."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ebbkc, oracle, vbbkc

from conftest import random_graph


@given(st.integers(0, 10_000), st.integers(3, 6))
@settings(max_examples=40, deadline=None)
def test_counts_match_bruteforce(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    ref = oracle.count_kcliques_brute(g, k)
    for order in ("hybrid", "truss", "color"):
        for et in (0, 2, 3):
            r = ebbkc.count(g, k, order=order, et_t=et)
            assert r.count == ref, (order, et, r.count, ref)


@given(st.integers(0, 10_000), st.integers(3, 5))
@settings(max_examples=25, deadline=None)
def test_vbbkc_matches(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    ref = oracle.count_kcliques_brute(g, k)
    for variant in ("degen", "ddegcol", "ddegcol+"):
        assert vbbkc.count(g, k, variant=variant).count == ref


@given(st.integers(0, 10_000), st.integers(3, 5))
@settings(max_examples=20, deadline=None)
def test_listing_exact(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    got, _ = ebbkc.list_cliques(g, k)
    exp = sorted(oracle.list_kcliques_brute(g, k))
    assert sorted(map(tuple, got.tolist())) == exp
    # every listed clique is sorted and unique
    assert len({tuple(r) for r in got.tolist()}) == len(got)


def test_rule2_prunes_but_preserves_count():
    rng = np.random.default_rng(3)
    g = random_graph(rng, n_lo=14, n_hi=18, p_lo=0.4, p_hi=0.6)
    k = 5
    with_r2 = ebbkc.count(g, k, order="hybrid", et_t=0, use_rule2=True)
    without = ebbkc.count(g, k, order="hybrid", et_t=0, use_rule2=False)
    assert with_r2.count == without.count
    assert with_r2.stats.pruned_color >= without.stats.pruned_color


def test_et_reduces_branches():
    """ET must cut branch count on dense graphs without changing results."""
    rng = np.random.default_rng(5)
    g = random_graph(rng, n_lo=16, n_hi=20, p_lo=0.7, p_hi=0.9)
    k = 6
    no_et = ebbkc.count(g, k, order="hybrid", et_t=0)
    et = ebbkc.count(g, k, order="hybrid", et_t=3)
    assert no_et.count == et.count
    assert et.stats.branches <= no_et.stats.branches
    assert et.stats.et_hits > 0


def test_k_edge_and_vertex_cases():
    rng = np.random.default_rng(9)
    g = random_graph(rng)
    assert ebbkc.count(g, 1).count == g.n
    assert ebbkc.count(g, 2).count == g.m
