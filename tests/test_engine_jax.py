"""Accelerator engine vs host engine: full pipeline equivalence."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ebbkc, engine_jax

from conftest import random_graph


@given(st.integers(0, 5000), st.integers(3, 6))
@settings(max_examples=15, deadline=None)
def test_jax_engine_matches_host(seed, k):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_lo=8, n_hi=26)
    ref = ebbkc.count(g, k).count
    got = ebbkc.count(g, k, backend="jax",
                      engine_kwargs={"interpret": True}).count
    assert got == ref


def test_et_routing_equivalence():
    rng = np.random.default_rng(11)
    g = random_graph(rng, n_lo=14, n_hi=22, p_lo=0.6, p_hi=0.9)
    for k in (4, 5, 6):
        ref = ebbkc.count(g, k).count
        a = ebbkc.count(g, k, backend="jax",
                        engine_kwargs={"interpret": True,
                                       "et_route": True}).count
        b = ebbkc.count(g, k, backend="jax",
                        engine_kwargs={"interpret": True,
                                       "et_route": False}).count
        assert a == ref and b == ref


def test_binning():
    rng = np.random.default_rng(3)
    g = random_graph(rng, n_lo=20, n_hi=30, p_lo=0.5, p_hi=0.8)
    binned = engine_jax.bin_tiles(g, 4)
    assert binned
    for T, packed in binned.items():
        assert packed.A.shape[1] == T
        assert packed.A.shape[2] == T // 32
        assert packed.cand.shape == (packed.A.shape[0], T // 32)


def test_count_packed_l_low():
    import jax.numpy as jnp
    rng = np.random.default_rng(4)
    g = random_graph(rng, n_lo=10, n_hi=16, p_lo=0.4, p_hi=0.7)
    binned = engine_jax.bin_tiles(g, 3)
    total1 = 0
    for T, packed in binned.items():
        hard, nv, t, f = engine_jax.count_packed(
            jnp.asarray(packed.A), jnp.asarray(packed.cand), 1,
            interpret=True)
        total1 += int(np.asarray(hard, np.int64).sum())
    assert total1 == ebbkc.count(g, 3).count
