"""Early-termination (Section 5): closed forms + kC2Plex/kCtPlex listings."""
from itertools import combinations
from math import comb

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import plex
from repro.core.bitops import popcount


def make_2plex(f, p):
    """f universal vertices + p non-adjacent pairs -> rows."""
    n = f + 2 * p
    full = (1 << n) - 1
    rows = []
    for v in range(n):
        r = full & ~(1 << v)
        if v >= f:  # paired vertex: remove its partner
            j = v - f
            partner = f + (j ^ 1)
            r &= ~(1 << partner)
        rows.append(r)
    return rows, full


@given(st.integers(0, 5), st.integers(0, 4), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_2plex_closed_form(f, p, l):
    rows, cand = make_2plex(f, p)
    if f + 2 * p == 0:
        return
    got = plex.count_in_2plex(rows, cand, l)
    # brute force
    n = f + 2 * p
    exp = 0
    for c in combinations(range(n), l):
        if all((rows[a] >> b) & 1 for a, b in combinations(c, 2)):
            exp += 1
    assert got == exp
    assert got == plex.count_2plex(f, p, l)


def test_2plex_complete_graph():
    # K_n is a 1-plex: count(l) = C(n, l)
    for n in (3, 5, 8):
        rows, cand = make_2plex(n, 0)
        for l in range(0, n + 1):
            assert plex.count_in_2plex(rows, cand, l) == comb(n, l)


@given(st.integers(0, 4), st.integers(0, 3), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_list_2plex_matches_count(f, p, l):
    rows, cand = make_2plex(f, p)
    if f + 2 * p == 0:
        return
    got = sorted(tuple(sorted(t)) for t in plex.list_2plex(rows, cand, l))
    assert len(got) == len(set(got))          # unique
    assert len(got) == plex.count_2plex(f, p, l)
    for t in got:                             # each is a clique
        for a, b in combinations(t, 2):
            assert (rows[a] >> b) & 1


@given(st.integers(0, 2000), st.integers(2, 5))
@settings(max_examples=40, deadline=None)
def test_list_tplex_on_dense_random(seed, l):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 10))
    full = (1 << n) - 1
    rows = [full & ~(1 << v) for v in range(n)]
    # remove a few random edges -> t-plex with small t
    for _ in range(int(rng.integers(0, n))):
        a, b = rng.integers(0, n, 2)
        if a != b:
            rows[a] &= ~(1 << int(b))
            rows[b] &= ~(1 << int(a))
    got = sorted(tuple(sorted(t)) for t in plex.list_tplex(rows, full, l))
    exp = []
    for c in combinations(range(n), l):
        if all((rows[a] >> b) & 1 for a, b in combinations(c, 2)):
            exp.append(c)
    assert got == sorted(exp)


def test_plexity_detection():
    rows, cand = make_2plex(3, 2)
    nv, t = plex.plexity(rows, cand)
    assert nv == 7 and t == 2
    F, rest = plex.split_universal(rows, cand)
    assert popcount(F) == 3 and popcount(rest) == 4
