"""Emission subsystem: listing kernel vs oracle, host/jax backend parity,
device-count/staging invariance, overflow -> host spill, sink API.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise
the multi-device emit dispatch (the CI matrix does both 1 and 4).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_graph
from repro.core import ebbkc, listing, oracle, pipeline
from repro.core.bitops import pack_mask, pack_rows
from repro.core.engine_np import Stats
from repro.data import rmat_graph
from repro.kernels import ops

N_DEV = jax.device_count()


def as_rows(arr):
    return list(map(tuple, arr.tolist()))


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T", [32, 64])
@pytest.mark.parametrize("l", [1, 2, 3, 4])
def test_list_kernel_matches_oracle_with_capacity_sweep(T, l):
    rng = np.random.default_rng(T * 10 + l)
    tiles = []
    for _ in range(4):
        g = random_graph(rng, n_lo=4, n_hi=min(T, 16), p_lo=0.3, p_hi=0.9)
        rows = [0] * g.n
        for u, v in g.edges.tolist():
            rows[u] |= 1 << v
            rows[v] |= 1 << u
        tiles.append((g, rows))
    A = np.stack([pack_rows(rows, T) for _, rows in tiles])
    cand = np.stack([pack_mask((1 << g.n) - 1, T) for g, _ in tiles])
    exp = [sorted(oracle.list_kcliques_brute(g, l)) for g, _ in tiles]
    for cap in (1, 3, max(max(map(len, exp)), 1)):
        bufs, cnt, ovf = ops.list_tiles(
            np.asarray(A), np.asarray(cand), l, capacity=cap, interpret=True
        )
        bufs, cnt, ovf = np.asarray(bufs), np.asarray(cnt), np.asarray(ovf)
        for b, want in enumerate(exp):
            # TRUE count survives overflow; flag is exact
            assert int(cnt[b]) == len(want)
            assert bool(ovf[b]) == (len(want) > cap)
            got = [tuple(r) for r in bufs[b][: min(len(want), cap)].tolist()]
            # buffer holds the DFS (lexicographic) prefix, exact-once
            assert got == want[: min(len(want), cap)]


def test_list_kernel_counts_match_count_kernel():
    rng = np.random.default_rng(3)
    g = random_graph(rng, n_lo=12, n_hi=20, p_lo=0.5, p_hi=0.9)
    T = 32
    rows = [0] * g.n
    for u, v in g.edges.tolist():
        rows[u] |= 1 << v
        rows[v] |= 1 << u
    A = np.asarray(pack_rows(rows, T)[None])
    cand = np.asarray(pack_mask((1 << g.n) - 1, T)[None])
    for l in (1, 2, 3, 4):
        counts = np.asarray(ops.count_tiles(A, cand, l, interpret=True))
        _, cnt, _ = ops.list_tiles(A, cand, l, capacity=4, interpret=True)
        assert counts.tolist() == np.asarray(cnt).tolist()


# ---------------------------------------------------------------------------
# property: both backends equal the brute-force clique SET (the satellite)
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000), st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_listing_equals_oracle_set_all_orderings(seed, k):
    """host and jax backends emit exactly the oracle's clique set --
    exact-once, members sorted -- for every ordering, including truncated
    ``max_out`` prefixes."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    exp = sorted(oracle.list_kcliques_brute(g, k))
    for order in ("hybrid", "truss", "color"):
        for backend in ("host", "jax"):
            got, _ = ebbkc.list_cliques(g, k, order=order, backend=backend)
            rows = as_rows(got)
            assert sorted(rows) == exp, (order, backend, k)
            assert len(set(rows)) == len(rows)  # exact-once
            assert all(list(r) == sorted(r) for r in rows)  # sorted members
            cap = max(1, len(exp) // 2)
            part, _ = ebbkc.list_cliques(
                g, k, order=order, backend=backend, max_out=cap
            )
            prows = as_rows(part)
            assert len(prows) == min(cap, len(exp)), (order, backend)
            assert set(prows) <= set(exp)
            assert len(set(prows)) == len(prows)


# ---------------------------------------------------------------------------
# engine level: device-count / staging / batch-size invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ["truss", "hybrid", "color"])
def test_listing_invariant_to_devices_and_staging(order):
    g = rmat_graph(7, 4, seed=7)
    for k in (4, 5):
        host, _ = ebbkc.list_cliques(g, k, order=order)
        base, _ = ebbkc.list_cliques(g, k, order=order, backend="jax")
        assert sorted(as_rows(base)) == sorted(as_rows(host)), (order, k)
        for kwargs in (
            dict(devices=1),
            dict(devices=N_DEV),
            dict(devices=N_DEV, async_staging=False),
            dict(devices=N_DEV, batch_size=16),
            dict(batch_size=16),
        ):
            got, st = ebbkc.list_cliques(
                g, k, order=order, backend="jax", engine_kwargs=kwargs
            )
            # not just the same set: the SAME deterministic batch order
            assert np.array_equal(got, base), (order, k, kwargs)
            assert st.emitted_cliques == len(base)


def test_multi_device_emit_accounts_devices():
    g = rmat_graph(8, 4, seed=7)
    k = 4
    got, st = ebbkc.list_cliques(
        g, k, backend="jax", engine_kwargs=dict(devices=N_DEV, batch_size=16)
    )
    host, _ = ebbkc.list_cliques(g, k)
    assert sorted(as_rows(got)) == sorted(as_rows(host))
    assert sum(st.device_tiles.values()) > 0
    assert set(st.device_flops) == set(st.device_tiles)
    if N_DEV > 1:
        assert len(st.device_tiles) > 1  # work actually spread


# ---------------------------------------------------------------------------
# overflow -> host spill (never truncate), oversize spill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("devices", [None, "dispatch"])
def test_emit_overflow_spills_to_host_never_truncates(devices):
    g = rmat_graph(7, 4, seed=7)
    k = 4
    host, _ = ebbkc.list_cliques(g, k)
    kwargs = dict(capacity=2)
    if devices == "dispatch":
        kwargs["devices"] = N_DEV
    got, st = ebbkc.list_cliques(g, k, backend="jax", engine_kwargs=kwargs)
    assert sorted(as_rows(got)) == sorted(as_rows(host))
    assert st.overflowed_tiles > 0
    assert st.emitted_cliques == len(host)


def test_max_capacity_cap_bounds_buffer_and_spills():
    g = rmat_graph(7, 4, seed=7)
    k = 4
    host, _ = ebbkc.list_cliques(g, k)
    kwargs = dict(max_capacity=4)
    got, st = ebbkc.list_cliques(g, k, backend="jax", engine_kwargs=kwargs)
    assert sorted(as_rows(got)) == sorted(as_rows(host))
    assert st.overflowed_tiles > 0


def test_oversize_tiles_spill_to_host_listing(rng):
    g = random_graph(rng, n_lo=42, n_hi=48, p_lo=0.96, p_hi=0.99)
    k = 4
    host, _ = ebbkc.list_cliques(g, k)
    kwargs = dict(bins=(32,))
    got, st = ebbkc.list_cliques(g, k, backend="jax", engine_kwargs=kwargs)
    assert sorted(as_rows(got)) == sorted(as_rows(host))
    assert st.spilled_tiles > 0
    assert all(s > 32 for s in st.spill_sizes)


# ---------------------------------------------------------------------------
# sinks and accounting
# ---------------------------------------------------------------------------


def test_array_sink_bounds_and_accounts():
    sink = listing.ArraySink(3, max_out=5)
    a = np.arange(12, dtype=np.int64).reshape(4, 3)
    assert sink.emit(a) == 4 and not sink.full
    assert sink.emit(a) == 1 and sink.full
    assert sink.emit(a) == 0
    assert sink.result().shape == (5, 3)
    assert sink.accepted == 5
    assert sink.bytes_written == 5 * 3 * 8


def test_callback_sink_streams_chunks():
    chunks = []
    sink = listing.CallbackSink(chunks.append)
    a = np.ones((2, 4), dtype=np.int64)
    assert sink.emit(a) == 2
    assert sink.emit(np.zeros((0, 4), dtype=np.int64)) == 0
    assert len(chunks) == 1 and chunks[0].shape == (2, 4)


def test_npz_sink_roundtrip(tmp_path):
    path = str(tmp_path / "cliques.npz")
    g = rmat_graph(6, 4, seed=7)
    k = 4
    sink = listing.NpzSink(path, k)
    res = listing.stream_cliques(g, k, sink)
    sink.close()
    host, _ = ebbkc.list_cliques(g, k)
    saved = np.load(path)["cliques"]
    assert sorted(as_rows(saved)) == sorted(as_rows(host))
    assert res.stats.emitted_cliques == len(host)
    assert res.stats.sink_bytes == saved.nbytes


def test_stream_cliques_rejects_small_k():
    g = rmat_graph(5, 3, seed=7)
    with pytest.raises(ValueError):
        listing.stream_cliques(g, 2, listing.ArraySink(2))


def test_decode_batch_roundtrip():
    """TileBatch.verts + kernel buffers decode to the host tile listing."""
    g = rmat_graph(6, 4, seed=7)
    k = 4
    stats = Stats()
    host, _ = ebbkc.list_cliques(g, k)
    rows = []
    for item in pipeline.stream_batches(g, k, order="hybrid"):
        assert isinstance(item, pipeline.TileBatch)
        assert item.verts.shape == (item.B, item.T)
        sizes = item.sizes.astype(np.int64)
        for b in range(item.B):
            members = item.verts[b, : sizes[b]]
            assert ((members >= 0) & (members < g.n)).all()
        arr = listing.list_batch(item, k - 2, stats, interpret=True)
        rows.extend(as_rows(arr))
    assert sorted(rows) == sorted(as_rows(host))
