"""Sharding rules: spec construction, divisibility fallbacks, mesh filter."""
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.sharding.rules import (LM_RULES, spec_for,
                                  transformer_param_specs,
                                  transformer_layer_specs)


def test_spec_for_basic():
    s = spec_for(LM_RULES, ("batch", "seq", "heads"))
    assert s == P(("pod", "data"), None, "model")


def test_kv_replication_fallback():
    cfg = configs.get("dbrx-132b").full          # kv=8 < TP=16
    specs = transformer_param_specs(cfg, model_size=16)
    assert specs["groups"]["global"]["wk"] == P(None, "data", None, None)
    assert specs["groups"]["global"]["wq"][2] == "model"
    cfg2 = configs.get("deepseek-moe-16b").full  # kv=16 == TP
    specs2 = transformer_param_specs(cfg2, model_size=16)
    assert specs2["groups"]["global"]["wk"][2] == "model"


def test_layer_specs_are_model_only():
    cfg = configs.get("gemma3-27b").full
    ls = transformer_layer_specs(cfg, model_size=16)
    for k, s in ls.items():
        for part in s:
            assert part in (None, "model"), (k, s)


def test_vocab_padding():
    cfg = configs.get("granite-3-8b").full
    assert cfg.vocab == 49155
    assert cfg.padded_vocab % 512 == 0
    assert cfg.padded_vocab >= cfg.vocab


def test_moe_expert_divisibility():
    for name in ("deepseek-moe-16b", "dbrx-132b"):
        cfg = configs.get(name).full
        assert cfg.moe.n_experts % 16 == 0, name  # model axis = 16
