"""On-device truss decomposition matches the host peeler exactly."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.truss import truss_decomposition
from repro.core.truss_jax import truss_decomposition_jax

from conftest import random_graph


@given(st.integers(0, 2000))
@settings(max_examples=15, deadline=None)
def test_jax_truss_matches_host(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_lo=5, n_hi=24)
    if g.m == 0:
        return
    td = truss_decomposition(g)
    truss_j, tau_j = truss_decomposition_jax(g)
    assert tau_j == td.tau
    np.testing.assert_array_equal(truss_j, td.trussness)


def test_jax_truss_medium_graph():
    from repro.data import powerlaw_graph
    g = powerlaw_graph(400, 8, seed=2)
    td = truss_decomposition(g)
    truss_j, tau_j = truss_decomposition_jax(g)
    assert tau_j == td.tau
    np.testing.assert_array_equal(truss_j, td.trussness)
