"""Single test home for the bit-manipulation helpers (core.bitops).

The helpers used to be duplicated between ``repro.kernels.common`` and
``repro.core.bitops``; they now live in bitops only, re-exported by
kernels.common -- this file asserts both the semantics and the dedup.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import bitops
from repro.kernels import common


def _rows_as_ints(dense):
    return [sum(1 << j for j in range(dense.shape[1]) if row[j]) for row in dense]


def test_gt_masks_matches_mask_gt():
    for T in (32, 64, 96):
        gt = bitops.gt_masks_np(T)
        assert gt.shape == (T, T // bitops.WORD) and gt.dtype == np.uint32
        clip = (1 << T) - 1
        for v in range(T):
            assert bitops.unpack_mask(gt[v]) == bitops.mask_gt(v) & clip


def test_pack_bits_matches_per_bit_packers():
    rng = np.random.default_rng(0)
    for T in (32, 64, 128):
        dense = rng.random((7, T)) < 0.4
        rows = _rows_as_ints(dense)
        got = bitops.pack_bits(dense)
        assert np.array_equal(got, bitops.pack_rows(rows, T)[:7])
        for i, r in enumerate(rows):
            assert np.array_equal(got[i], bitops.pack_mask(r, T))
            assert bitops.unpack_mask(got[i]) == r


def test_pack_rows_dense_roundtrip():
    rng = np.random.default_rng(1)
    T = 64
    dense = (rng.random((T, T)) < 0.3).astype(np.uint8)
    dense = np.triu(dense, 1)
    dense = dense | dense.T
    rows = _rows_as_ints(dense.astype(bool))
    assert np.array_equal(bitops.dense_from_rows(rows, T), dense)


def test_traced_helpers_match_host_ints():
    rng = np.random.default_rng(2)
    T = 96
    dense = rng.random((5, T)) < 0.5
    rows = _rows_as_ints(dense)
    packed = jnp.asarray(bitops.pack_bits(dense))
    # per-word popcount sums to the python-int popcount
    pc = np.asarray(bitops.popcount_words(packed)).sum(axis=-1)
    assert pc.tolist() == [bitops.popcount(r) for r in rows]
    # unpack_bits reproduces the bit positions of bits()
    ub = np.asarray(bitops.unpack_bits(packed, T))
    for i, r in enumerate(rows):
        assert np.nonzero(ub[i])[0].tolist() == list(bitops.bits(r))
    # bit_at agrees with direct bit tests
    for v in (0, 1, 31, 32, 63, 95):
        got = np.asarray(bitops.bit_at(packed, v))
        assert got.tolist() == [(r >> v) & 1 for r in rows]


def test_bits_iterates_ascending():
    x = (1 << 0) | (1 << 31) | (1 << 32) | (1 << 70)
    assert list(bitops.bits(x)) == [0, 31, 32, 70]
    assert bitops.mask_lt(5) == 0b11111


def test_kernels_common_reexports_single_definitions():
    assert common.gt_masks_np is bitops.gt_masks_np
    assert common.popcount is bitops.popcount_words
    assert common.unpack_bits is bitops.unpack_bits
    assert common.bit_at is bitops.bit_at
    assert common.num_words is bitops.num_words
    assert common.WORD == bitops.WORD
