"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G, oracle
from repro.core.bitops import pack_mask, pack_rows
from repro.kernels import ops, ref
from repro.kernels.common import gt_masks_np, pascal_table


def make_tiles(rng, B, T, p_lo=0.2, p_hi=0.9):
    As, cands, gs = [], [], []
    for _ in range(B):
        s = int(rng.integers(2, T + 1))
        p = float(rng.uniform(p_lo, p_hi))
        mask = rng.random((s, s)) < p
        edges = [(i, j) for i in range(s) for j in range(i + 1, s)
                 if mask[i, j]]
        g = G.from_edges(s, edges)
        rows = [0] * s
        for u, v in edges:
            rows[u] |= 1 << v
            rows[v] |= 1 << u
        As.append(pack_rows(rows, T))
        cands.append(pack_mask((1 << s) - 1, T))
        gs.append(g)
    return jnp.asarray(np.stack(As)), jnp.asarray(np.stack(cands)), gs


@pytest.mark.parametrize("T", [32, 64, 128])
@pytest.mark.parametrize("l", [1, 2, 3, 4, 5])
def test_dfs_kernel_shape_sweep(T, l):
    rng = np.random.default_rng(T * 100 + l)
    A, cand, gs = make_tiles(rng, 6, min(T, 24))
    # re-pack at width T
    A = jnp.pad(A, ((0, 0), (0, T - A.shape[1]), (0, T // 32 - A.shape[2])))
    cand = jnp.pad(cand, ((0, 0), (0, T // 32 - cand.shape[1])))
    method = "dfs" if l >= 3 else "ref"
    got = np.asarray(ops.count_tiles(A, cand, l, method=method,
                                     interpret=True))
    exp = np.asarray([oracle.count_kcliques_brute(g, l) for g in gs],
                     dtype=np.uint32)
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("T", [32, 64])
def test_mxu_triangle_kernel(T):
    rng = np.random.default_rng(T)
    A, cand, gs = make_tiles(rng, 9, T)
    got = np.asarray(ops.triangles(A, cand, interpret=True))
    exp = np.asarray([oracle.count_kcliques_brute(g, 3) for g in gs],
                     dtype=np.uint32)
    np.testing.assert_array_equal(got, exp)
    # and against the einsum oracle
    np.testing.assert_array_equal(
        got, np.asarray(ref.triangle_count_tiles_ref(A, cand)))


@pytest.mark.parametrize("T", [32, 64])
def test_intersect_kernel(T):
    rng = np.random.default_rng(T + 1)
    A, cand, gs = make_tiles(rng, 8, T)
    pairs = []
    for g in gs:
        pairs.append(g.edges[0].astype(np.int32) if g.m
                     else np.array([0, 1], np.int32))
    pairs = jnp.asarray(np.stack(pairs))
    c1, n1 = ops.edge_candidates(A, pairs, interpret=True)
    c2, n2 = ref.edge_candidates_ref(A, pairs)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))


def test_dfs_vs_expansion_ref_cross_check():
    rng = np.random.default_rng(99)
    A, cand, _ = make_tiles(rng, 5, 32)
    for l in (3, 4, 5, 6):
        a = np.asarray(ops.count_tiles(A, cand, l, method="dfs",
                                       interpret=True))
        b = np.asarray(ref.clique_count_tiles_ref(A, cand, l))
        np.testing.assert_array_equal(a, b)


def test_gt_masks():
    gt = gt_masks_np(64)
    assert gt.shape == (64, 2)
    for v in (0, 31, 32, 63):
        bits = np.unpackbits(gt[v].view(np.uint8), bitorder="little")
        expected = np.zeros(64, np.uint8)
        expected[v + 1:] = 1
        np.testing.assert_array_equal(bits, expected)


def test_pascal_table():
    from math import comb
    t = pascal_table(20)
    for n in range(21):
        for r in range(n + 1):
            assert t[n, r] == comb(n, r)
