"""Observability layer: trace determinism and schema shape, disabled-path
overhead budget, the Stats.merge classification table, metrics registry +
Prometheus exposition, and the serve-tier per-request stage breakdown.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to check
that trace *structure* is device-count invariant (the CI matrix does 1
and 4).
"""

import dataclasses
import json
import threading
import time
from collections import Counter as TallyCounter

import numpy as np
import pytest

from conftest import random_graph
from repro.core import engine_jax, pipeline
from repro.core.engine_np import Stats
from repro.obs import metrics as obs_metrics
from repro.obs import trace
from repro.obs.export import MetricsServer, render_prometheus, scrape
from repro.obs.logging import get_logger, setup_logging
from repro.obs.profile import aggregate_device_spans, note_kernel
from repro.serve import CliqueService


@pytest.fixture
def tracer():
    """Enabled process tracer, reset and disabled again afterwards."""
    trace.configure(enabled=True)
    trace.reset()
    yield trace
    trace.configure(enabled=False)
    trace.reset()


@pytest.fixture
def registry():
    """A private metrics registry (the global one is left alone)."""
    return obs_metrics.Registry()


def small_graph(seed=11):
    rng = np.random.default_rng(seed)
    return random_graph(rng, n_lo=28, n_hi=29, p_lo=0.3, p_hi=0.3)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_spans_nest_and_validate(tracer):
    with trace.span("outer", x=1):
        with trace.span("inner") as sp:
            sp.set(y=2)
        trace.instant("tick")
    recs = trace.span_records()
    assert ("inner", "outer") in recs
    assert ("outer", None) in recs
    doc = trace.chrome_trace()
    assert trace.validate_chrome_trace(doc) == []
    by_name = {e["name"]: e for e in doc["traceEvents"] if e.get("ph") == "X"}
    assert by_name["inner"]["args"] == {"y": 2}
    # inner lies within outer
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]


def test_thread_local_nesting(tracer):
    def worker():
        with trace.span("w-outer"):
            with trace.span("w-inner"):
                pass

    with trace.span("main"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    recs = trace.span_records()
    # the worker's spans never parent onto the main thread's open span
    assert ("w-inner", "w-outer") in recs
    assert ("w-outer", None) in recs
    assert ("main", None) in recs


def test_async_request_track(tracer):
    trace.async_begin("request", id=7, k=5)
    trace.async_instant("request/admit", id=7)
    trace.async_end("request", id=7, latency_ms=1.5)
    doc = trace.chrome_trace()
    assert trace.validate_chrome_trace(doc) == []
    phs = [e["ph"] for e in doc["traceEvents"] if e.get("id") == "7"]
    assert phs == ["b", "n", "e"]


def test_unmatched_async_flagged(tracer):
    trace.async_begin("request", id=9)
    problems = trace.validate_chrome_trace(trace.chrome_trace())
    assert any("begin without end" in p for p in problems)


def test_retroactive_complete(tracer):
    t0 = time.perf_counter_ns()
    trace.complete("reorder/park", t0, 1500, rid=3)
    (ev,) = [e for e in trace.events() if e["name"] == "reorder/park"]
    assert ev["ph"] == "X" and ev["dur"] == 1500


def test_ring_buffer_drops_oldest(tracer):
    try:
        trace.configure(enabled=True, capacity=8)
        for i in range(20):
            trace.instant(f"e{i}")
        evs = trace.events()
        assert len(evs) == 8
        assert evs[0]["name"] == "e12" and trace.dropped() == 12
    finally:
        trace.configure(enabled=True, capacity=trace._DEFAULT_CAPACITY)


def test_validate_rejects_malformed():
    assert trace.validate_chrome_trace({}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0}]}
    assert any("dur" in p or "pid" in p or "tid" in p
               for p in trace.validate_chrome_trace(bad))


# ---------------------------------------------------------------------------
# trace determinism + overhead budget (tentpole acceptance)
# ---------------------------------------------------------------------------


def _traced_pipeline_structure(g, k):
    """(name, parent) multiset of one serial-packed pipeline run."""
    trace.reset()
    plan = pipeline.build_plan(g, order="hybrid")
    for _ in pipeline.stream_batches(plan, k, batch_size=64,
                                     pack_workers=0):
        pass
    return TallyCounter(trace.span_records())


def test_trace_structure_deterministic(tracer):
    g = small_graph()
    first = _traced_pipeline_structure(g, 4)
    assert first, "pipeline produced no spans"
    assert {"extract", "pack"} <= {name for name, _ in first}
    for _ in range(2):
        assert _traced_pipeline_structure(g, 4) == first


def test_trace_well_nested_under_load(tracer):
    # serve a small concurrent workload; every sync span must close and
    # every request track must be begin/end matched
    g = small_graph(5)
    with CliqueService() as svc:
        svc.register_graph("g", g)
        tickets = [svc.submit("g", k, mode) for k in (3, 4)
                   for mode in ("count", "list")]
        for t in tickets:
            t.result(timeout=120)
    doc = trace.chrome_trace()
    assert trace.validate_chrome_trace(doc) == []
    begins = [e for e in doc["traceEvents"] if e.get("ph") == "b"]
    assert len(begins) == len(tickets)


def test_disabled_tracer_overhead_budget():
    # the contract: tracing disabled adds <= 1% to bench-smoke-like work.
    # Measured as (per-disabled-span cost) * (spans the workload emits),
    # which is robust where wall-clock diffing is noise-dominated.
    g = small_graph(23)
    trace.configure(enabled=False)

    def workload():
        t0 = time.perf_counter()
        engine_jax.count(g, 4, batch_size=64)
        return time.perf_counter() - t0

    workload()  # warm executables/plan caches
    work_s = min(workload() for _ in range(3))

    trace.configure(enabled=True)
    trace.reset()
    engine_jax.count(g, 4, batch_size=64)
    n_spans = len(trace.events())
    trace.configure(enabled=False)
    trace.reset()
    assert n_spans > 0

    n_iter = 50_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        with trace.span("x", a=1):
            pass
    per_call = (time.perf_counter() - t0) / n_iter
    overhead = per_call * n_spans
    assert overhead <= 0.01 * work_s, (
        f"disabled tracing would add {overhead * 1e3:.3f}ms over "
        f"{n_spans} spans to a {work_s * 1e3:.1f}ms workload (> 1%)"
    )


def test_engine_trace_covers_device_stages(tracer):
    g = small_graph(31)
    engine_jax.count(g, 4, batch_size=64, devices="all")
    names = {name for name, _ in trace.span_records()}
    assert {"extract", "pack", "device/stage", "device/harvest",
            "combine"} <= names
    doc = trace.chrome_trace()
    assert trace.validate_chrome_trace(doc) == []
    # device spans carry kernel-signature attribution for the roofline
    rows = aggregate_device_spans(doc)
    assert rows and any(r["flops"] > 0 for r in rows)


# ---------------------------------------------------------------------------
# Stats.merge (the single classification table)
# ---------------------------------------------------------------------------


def test_stats_merge_all_fields_classified():
    # tripwire: adding a Stats field without classifying it must fail
    # loudly in merge, not silently drift between merge and metrics
    fields = {f.name for f in dataclasses.fields(Stats)}
    assert fields == set(Stats._MERGE_KINDS)
    assert fields == set(Stats._METRIC_KINDS)


def test_stats_merge_combines():
    a = Stats(branches=2, peak_graph=10, device_tiles={0: 3},
              spill_sizes=[4], backend="lax", plan_cache_hit=False,
              pack_queue_occupancy=0.5)
    b = Stats(branches=3, peak_graph=7, device_tiles={0: 1, 1: 2},
              spill_sizes=[9], backend="lax", plan_cache_hit=True,
              pack_queue_occupancy=0.75)
    a.merge(b)
    assert a.branches == 5
    assert a.peak_graph == 10
    assert a.device_tiles == {0: 4, 1: 2}
    assert a.spill_sizes == [4, 9]
    assert a.plan_cache_hit is True
    assert a.pack_queue_occupancy == 0.75
    assert a.backend == "lax"


def test_stats_merge_rejects_unclassified():
    @dataclasses.dataclass
    class Odd(Stats):
        novel_field: int = 0

    with pytest.raises(TypeError, match="novel_field"):
        Odd().merge(Odd())


def test_stats_merge_keeps_info_identity():
    a, b = Stats(), Stats(backend="pallas")
    a.merge(b)
    assert a.backend == "pallas"  # empty self adopts other's identity
    a.merge(Stats(backend="lax"))
    assert a.backend == "pallas"  # non-empty self wins


# ---------------------------------------------------------------------------
# metrics registry + exposition
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram(registry):
    c = registry.counter("repro_t_total", help="h")
    c.inc(3)
    c.inc()
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = registry.gauge("repro_g")
    g.set(2.5)
    g.set_max(1.0)
    assert g.value == 2.5
    h = registry.histogram("repro_h", edges=[1.0, 2.0])
    for v in (0.5, 1.5, 99.0):
        h.observe(v)
    counts, total, n = h.snapshot()
    assert counts == [1, 1, 1] and n == 3 and total == pytest.approx(101.0)


def test_registry_get_or_create_and_label_identity(registry):
    a = registry.counter("repro_x_total", key="0")
    b = registry.counter("repro_x_total", key="0")
    c = registry.counter("repro_x_total", key="1")
    assert a is b and a is not c
    with pytest.raises(TypeError):
        registry.gauge("repro_x_total", key="0")


def test_observe_stats_and_publish_totals(registry):
    st = Stats(branches=4, device_tiles={0: 2, 1: 1}, spilled_tiles=1,
               peak_graph=9, plan_cache_hit=True, backend="lax")
    obs_metrics.observe_stats(st, "repro_engine", registry)
    obs_metrics.observe_stats(st, "repro_engine", registry)
    got = {(m.name, m.labels): m for m in registry.collect()}
    assert got[("repro_engine_branches_total", ())].value == 8
    assert got[("repro_engine_device_tiles_total",
                (("key", "0"),))].value == 4
    assert got[("repro_engine_peak_graph", ())].value == 9
    # publish_totals is absolute, not additive
    reg2 = obs_metrics.Registry()
    obs_metrics.publish_totals(st, "repro_engine", reg2)
    obs_metrics.publish_totals(st, "repro_engine", reg2)
    got2 = {m.name: m for m in reg2.collect()}
    assert got2["repro_engine_branches_total"].value == 4


def _parse_exposition(text):
    """Minimal 0.0.4 parser: {metric-with-labels: value}; validates shape."""
    out = {}
    types = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split()
            assert kind in ("counter", "gauge", "histogram"), line
            types[name] = kind
        elif line.startswith("#"):
            assert line.startswith("# HELP"), line
        else:
            key, val = line.rsplit(" ", 1)
            float(val)  # must parse
            out[key] = float(val)
    return out, types


def test_prometheus_render_parses(registry):
    registry.counter("repro_a_total", help="things").inc(2)
    registry.gauge("repro_b", key="x").set(1.5)
    registry.histogram("repro_c_seconds", edges=[0.1, 1.0]).observe(0.05)
    text = render_prometheus(registry)
    values, types = _parse_exposition(text)
    assert values['repro_a_total'] == 2
    assert values['repro_b{key="x"}'] == 1.5
    assert types["repro_c_seconds"] == "histogram"
    assert values['repro_c_seconds_bucket{le="+Inf"}'] == 1
    assert values["repro_c_seconds_count"] == 1
    # histogram buckets are cumulative and ordered
    assert values['repro_c_seconds_bucket{le="0.1"}'] <= \
        values['repro_c_seconds_bucket{le="1"}']


def test_metrics_server_scrape(registry):
    registry.counter("repro_up_total").inc()
    calls = []
    registry.add_collector(lambda: calls.append(1))
    srv = MetricsServer(port=0, registry=registry)
    try:
        text = scrape(srv.address)
    finally:
        srv.close()
    assert calls, "collector did not run at scrape time"
    values, _ = _parse_exposition(text)
    assert values["repro_up_total"] == 1


def test_note_kernel_attribution(registry):
    note_kernel("count[l=3,T=64,B=256,backend=lax]", compile_s=0.5,
                registry=registry)
    note_kernel("count[l=3,T=64,B=256,backend=lax]", execute_s=0.25,
                calls=1, flops=1e9, nbytes=1e6, registry=registry)
    got = {m.name for m in registry.collect()}
    assert "repro_kernel_compile_seconds_total" in got
    assert "repro_kernel_execute_seconds_total" in got


# ---------------------------------------------------------------------------
# logging + serve integration
# ---------------------------------------------------------------------------


def test_setup_logging_idempotent():
    root = setup_logging("info")
    n = len(root.handlers)
    assert setup_logging("debug") is root
    assert len(root.handlers) == n
    log = get_logger("test_obs")
    assert log.name == "repro.test_obs"
    with pytest.raises(ValueError):
        setup_logging("shout")


def test_serve_stage_breakdown_and_metrics_endpoint(tracer):
    g = small_graph(47)
    svc = CliqueService(metrics_port=0)
    try:
        svc.register_graph("g", g)
        res = svc.submit("g", 4, "count").result(timeout=120)
        assert "queue" in res.stage_s
        assert "device" in res.stage_s
        assert all(v >= 0 for v in res.stage_s.values())
        lst = svc.submit("g", 4, "list").result(timeout=120)
        assert "reorder" in lst.stage_s
        text = scrape(svc.metrics_address)
        values, types = _parse_exposition(text)
        assert values["repro_serve_completed_total"] == 2
        assert types["repro_request_latency_seconds"] == "histogram"
        assert any(k.startswith("repro_engine_") for k in values)
        assert any(k.startswith("repro_request_stage_seconds_total")
                   for k in values)
    finally:
        svc.close()
    assert svc.metrics_address is None
    # request rollup went through Stats.merge: listing emitted cliques
    assert svc.request_stats.emitted_cliques == lst.emitted
