"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax.numpy as jnp

from repro.core import ebbkc, engine_jax, vbbkc
from repro.data import planted_cliques, powerlaw_graph
from repro.runtime.clique_scheduler import schedule_tiles


def test_end_to_end_planted_clique_recovery():
    """Full pipeline (truss order -> tiles -> device engine) recovers the
    planted structure; all backends and the baseline agree."""
    g = planted_cliques(300, 4, 10, p_noise=0.005, seed=9)
    for k in (4, 6, 8):
        host = ebbkc.count(g, k, order="hybrid", et_t=3)
        dev = ebbkc.count(g, k, backend="jax",
                          engine_kwargs={"interpret": True})
        base = vbbkc.count(g, k, variant="ddegcol+")
        assert host.count == dev.count == base.count
        if k == 8:
            assert host.count >= 4 * 45  # C(10,8)=45 per planted clique


def test_distributed_schedule_then_count():
    """EP scheduling (Section 6.2(7)) partitions tiles; per-bin counting
    sums to the global answer (the multi-device reduction is a psum of
    exactly these partials)."""
    g = powerlaw_graph(800, 10, seed=4)
    k = 5
    binned = engine_jax.bin_tiles(g, k)
    total = 0
    for T, packed in binned.items():
        class _T:
            def __init__(self, s, e):
                self.s, self.nedges = s, e
        metas = [_T(T, T * 2) for _ in range(packed.A.shape[0])]
        device_bins, stats = schedule_tiles(metas, k - 2, n_devices=4)
        assert stats["max_over_mean"] < 1.5
        for bin_ids in device_bins:
            if not bin_ids:
                continue
            idx = np.asarray(bin_ids)
            hard, nv, t, f = engine_jax.count_packed(
                jnp.asarray(packed.A[idx]), jnp.asarray(packed.cand[idx]),
                k - 2, et=True, interpret=True)
            total += engine_jax.combine_counts(hard, nv, t, f, k - 2, True)
    assert total == ebbkc.count(g, k).count


def test_listing_service_bounded_output():
    g = planted_cliques(120, 3, 8, p_noise=0.01, seed=5)
    out, _ = ebbkc.list_cliques(g, 4, max_out=50)
    assert out.shape[1] == 4
    assert len(out) >= 50  # buffer filled
    # all outputs are real cliques
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    from itertools import combinations
    for row in out[:50].tolist():
        for a, b in combinations(row, 2):
            assert b in adj[a]
