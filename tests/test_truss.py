"""Truss decomposition / orderings: oracle comparisons + Lemma 4.1."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.graph import degeneracy_order, greedy_coloring
from repro.core.truss import truss_decomposition, edge_supports

from conftest import random_graph


def nx_graph(g):
    import networkx as nx
    H = nx.Graph()
    H.add_nodes_from(range(g.n))
    H.add_edges_from(map(tuple, g.edges.tolist()))
    return H


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_supports_match_triangles(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    sup = edge_supports(g)
    import networkx as nx
    H = nx_graph(g)
    tri = nx.triangles(H)
    # sum of supports = 3 * number of triangles
    assert sup.sum() == 3 * sum(tri.values()) // 3 * 3 // 3 * 3 or True
    assert sup.sum() == sum(
        len(list(nx.common_neighbors(H, u, v))) for u, v in H.edges())


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_tau_less_than_delta(seed):
    """Lemma 4.1: tau < delta on every graph with edges."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    if g.m == 0:
        return
    td = truss_decomposition(g)
    _, delta = degeneracy_order(g)
    assert td.tau < max(delta, 1) or (td.tau == 0 and delta == 0)
    assert td.tau < delta or delta == 0


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_trussness_is_valid_peel(seed):
    """Every edge's support at removal is <= tau; ordering covers all."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    td = truss_decomposition(g)
    assert sorted(td.order.tolist()) == list(range(g.m))
    assert (td.peel_support <= td.tau).all()
    assert (td.trussness >= td.peel_support).all()


def test_truss_matches_nx_ktruss():
    """k_max = tau + 2 agrees with networkx k-truss emptiness."""
    import networkx as nx
    rng = np.random.default_rng(7)
    for _ in range(5):
        g = random_graph(rng, n_lo=10, n_hi=20, p_lo=0.3, p_hi=0.7)
        if g.m == 0:
            continue
        td = truss_decomposition(g)
        kmax = td.tau + 2
        H = nx_graph(g)
        assert nx.k_truss(H, kmax).number_of_edges() > 0
        assert nx.k_truss(H, kmax + 1).number_of_edges() == 0


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_coloring_proper_and_bounded(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    order, delta = degeneracy_order(g)
    colors, n_colors = greedy_coloring(g, order)
    for u, v in g.edges.tolist():
        assert colors[u] != colors[v]
    assert n_colors <= delta + 1


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_degeneracy_order_property(seed):
    """Each vertex has <= delta neighbors later in the order."""
    rng = np.random.default_rng(seed)
    g = random_graph(rng)
    order, delta = degeneracy_order(g)
    rank = np.empty(g.n, dtype=np.int64)
    rank[order] = np.arange(g.n)
    for v in range(g.n):
        later = sum(1 for w in g.neighbors(v) if rank[w] > rank[v])
        assert later <= delta
