"""Differential fuzzing: host engine vs jax engine vs brute-force oracle.

Random graphs -- including the degenerate shapes that historically break
clique listers (stars, complete graphs, disconnected unions with isolated
vertices, triangle-free rings/bipartite graphs, barbells, and multigraph
edge-lists with duplicate edges that ``from_edges`` must canonicalize) --
are pushed through every ordering x engine x k in 3..6 and must agree
exactly with the brute-force oracle: counts AND the listed clique sets.

Runs under real ``hypothesis`` when installed (CI) and under the
deterministic shim in ``tests/conftest.py`` otherwise.  Seeds that ever
exposed a disagreement belong in ``REGRESSION_SEEDS`` below so they run
forever as plain parametrized cases.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ebbkc, engine_jax, oracle
from repro.core.graph import from_edges

FAMILIES = ("gnp", "star", "clique", "disconnected", "ring", "bipartite",
            "barbell")

#: seeds kept as permanent regression cases: one per graph family (the
#: family is seed % len(FAMILIES)), chosen to cover the shapes that stress
#: distinct code paths -- hub-only stars (every tile is empty or tiny),
#: complete graphs (maximal tiles, ET closed form), disconnected unions
#: (isolated vertices + independent components), triangle-free graphs
#: (zero tiles survive select), and duplicate-edge inputs (seed % 3 == 0
#: appends reversed duplicates + self loops that canonicalization drops)
REGRESSION_SEEDS = [0, 1, 2, 3, 4, 5, 6, 9, 16, 30, 1023]


def graph_from_seed(seed: int):
    """Deterministic graph for one fuzz example (family = seed % len)."""
    rng = np.random.default_rng(seed)
    fam = FAMILIES[seed % len(FAMILIES)]
    if fam == "gnp":
        n = int(rng.integers(4, 15))
        mask = np.triu(rng.random((n, n)) < float(rng.uniform(0.1, 0.9)), 1)
        e = np.argwhere(mask)
    elif fam == "star":
        n = int(rng.integers(4, 16))
        e = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], 1)
        # a few chords so some triangles go through the hub
        e = np.concatenate(
            [e, np.argwhere(np.triu(rng.random((n, n)) < 0.1, 1))])
    elif fam == "clique":
        n = int(rng.integers(4, 11))
        e = np.argwhere(np.triu(np.ones((n, n), bool), 1))
    elif fam == "disconnected":
        blocks, off = [], 0
        for s in rng.integers(2, 6, size=3):
            blocks.append(
                np.argwhere(np.triu(np.ones((s, s), bool), 1)) + off)
            off += int(s)
        n = off + int(rng.integers(0, 3))  # trailing isolated vertices
        e = np.concatenate(blocks)
    elif fam == "ring":
        n = int(rng.integers(5, 16))  # girth n: triangle-free
        e = np.stack([np.arange(n), (np.arange(n) + 1) % n], 1)
    elif fam == "bipartite":
        a, b = int(rng.integers(2, 7)), int(rng.integers(2, 7))
        e = np.argwhere(rng.random((a, b)) < 0.7)
        e = e + np.array([0, a])
        n = a + b  # triangle-free
    else:  # barbell: two s-cliques joined by one bridge edge
        s = int(rng.integers(3, 7))
        c1 = np.argwhere(np.triu(np.ones((s, s), bool), 1))
        e = np.concatenate([c1, c1 + s, np.array([[s - 1, s]])])
        n = 2 * s
    e = e.reshape(-1, 2).astype(np.int64)
    if e.shape[0] and seed % 3 == 0:
        # multigraph fuzz: duplicate edges (reversed) + self loops; the
        # canonical Graph must be identical to the clean edge list's
        dup = e[rng.integers(0, e.shape[0], size=min(5, e.shape[0]))]
        loops = np.stack([np.arange(min(3, n), dtype=np.int64)] * 2, 1)
        e = np.concatenate([e, dup[:, ::-1], loops])
    return fam, from_edges(n, e)


def _rows_sorted(arr: np.ndarray) -> np.ndarray:
    if arr.shape[0] == 0:
        return arr
    return arr[np.lexsort(arr.T[::-1])]


def check_seed(seed: int, ks=(3, 4, 5, 6), backends=(None,),
               with_listing=True):
    fam, g = graph_from_seed(seed)
    for k in ks:
        want = oracle.count_kcliques_brute(g, k)
        want_rows = np.asarray(sorted(oracle.list_kcliques_brute(g, k)),
                               dtype=np.int64).reshape(-1, k)
        for order in ("truss", "hybrid", "color"):
            r = ebbkc.count(g, k, order=order)
            assert r.count == want, (seed, fam, k, order, r.count, want)
            rows, _ = ebbkc.list_cliques(g, k, order=order)
            assert np.array_equal(_rows_sorted(rows), want_rows), \
                (seed, fam, k, order, "host listing")
        for backend in backends:
            rj = engine_jax.count(g, k, backend=backend)
            assert rj.count == want, (seed, fam, k, backend, rj.count, want)
            if with_listing:
                rows, _ = ebbkc.list_cliques(
                    g, k, backend="jax",
                    engine_kwargs=dict(backend=backend))
                assert np.array_equal(_rows_sorted(rows), want_rows), \
                    (seed, fam, k, backend, "jax listing")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_fuzz_differential(seed):
    """Random seeds: host (all orderings) and jax (session backend) vs
    the brute-force oracle, counting and listing, k in 3..6."""
    check_seed(seed)


@pytest.mark.parametrize("seed", REGRESSION_SEEDS)
def test_regression_seeds(seed):
    """Committed regression corpus (see module docstring) -- these run on
    every backend the registry serves off-TPU, not just the session one,
    so a backend-specific divergence cannot hide behind REPRO_BACKEND."""
    check_seed(seed, ks=(3, 4, 5), backends=("lax", "pallas"),
               with_listing=(seed % 2 == 0))


def test_empty_and_tiny_graphs():
    """No-edge / single-edge / single-triangle graphs through every path."""
    for n, edges in ((0, []), (1, []), (5, []), (2, [(0, 1)]),
                     (3, [(0, 1), (1, 2), (0, 2)])):
        g = from_edges(n, np.asarray(edges, np.int64).reshape(-1, 2))
        for k in (3, 4):
            want = oracle.count_kcliques_brute(g, k)
            for order in ("truss", "hybrid", "color"):
                assert ebbkc.count(g, k, order=order).count == want
            assert engine_jax.count(g, k).count == want
            rows, _ = ebbkc.list_cliques(g, k, backend="jax")
            assert rows.shape == (want, k)


#: permanent corpus for the edge-churn family: one seed per graph family
#: (family = seed % len(FAMILIES)), so repair is exercised against every
#: degenerate shape above.  Seed 5 rides along with the committed closure
#: regression in tests/test_delta.py (a survivor's tile retired without a
#: replacement when two deleted edges shared a common neighborhood).
CHURN_REGRESSION_SEEDS = [0, 1, 2, 3, 4, 5, 6]


def check_churn_seed(seed: int, ks=(3, 4, 5, 6), n_batches=3):
    """One churn example: random insert/delete batches over a fuzz graph.

    After every batch, for every ordering: the incrementally repaired
    plan (repair forced via churn_threshold > 1, except color which
    always takes the rebuild fallback) must agree with the brute oracle
    AND byte-for-byte (canonically sorted) with a from-scratch plan of
    the mutated graph, and the per-batch clique delta must equal the
    brute set difference of the two snapshots.
    """
    from repro.core import pipeline
    from repro.core.graph import apply_edge_batch
    from repro.delta import repair_plan
    from repro.delta.query import delta_cliques

    fam, g = graph_from_seed(seed)
    rng = np.random.default_rng(np.uint64(seed) * 2654435761 % 2**63)
    orders = ("truss", "hybrid", "color")
    plans = {o: pipeline.build_plan(g, o) for o in orders}
    for b in range(n_batches):
        ins = rng.integers(0, g.n, (int(rng.integers(1, 6)), 2)) \
            if g.n else None
        dele = g.edges[rng.choice(
            g.m, min(g.m, int(rng.integers(1, 4))), replace=False)] \
            if g.m else None
        g2 = apply_edge_batch(g, insert=ins, delete=dele)
        old_rows = {k: {tuple(r) for r in oracle.list_kcliques_brute(g, k)}
                    for k in ks}
        for order in orders:
            plan2, info = repair_plan(plans[order], g2, order,
                                      churn_threshold=1.1)
            assert info.rebuilt == (order == "color"), (seed, fam, b, order)
            scratch = pipeline.build_plan(g2, order)
            for k in ks:
                want = oracle.count_kcliques_brute(g2, k)
                want_rows = np.asarray(
                    sorted(oracle.list_kcliques_brute(g2, k)),
                    dtype=np.int64).reshape(-1, k)
                got = ebbkc.count(g2, k, order=order, plan=plan2).count
                assert got == want, (seed, fam, b, order, k, got, want)
                rows, _ = ebbkc.list_cliques(g2, k, order=order, plan=plan2)
                srows, _ = ebbkc.list_cliques(g2, k, order=order,
                                              plan=scratch)
                assert np.array_equal(_rows_sorted(rows), want_rows), \
                    (seed, fam, b, order, k, "repaired listing vs oracle")
                assert np.array_equal(_rows_sorted(rows),
                                      _rows_sorted(srows)), \
                    (seed, fam, b, order, k, "repaired vs from-scratch")
                d = delta_cliques(plans[order], plan2, info, k, order=order)
                new_rows = {tuple(r)
                            for r in oracle.list_kcliques_brute(g2, k)}
                assert {tuple(r) for r in d.gained} == \
                    new_rows - old_rows[k], (seed, fam, b, order, k, "gain")
                assert {tuple(r) for r in d.lost} == \
                    old_rows[k] - new_rows, (seed, fam, b, order, k, "lost")
            plans[order] = plan2
        g = g2


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_fuzz_edge_churn(seed):
    """Random seeds through the edge-churn family: incremental repair vs
    from-scratch plans vs the brute oracle, every ordering, k in 3..6."""
    check_churn_seed(seed)


@pytest.mark.parametrize("seed", CHURN_REGRESSION_SEEDS)
def test_churn_regression_seeds(seed):
    """Committed churn corpus: repair exercised over every graph family."""
    check_churn_seed(seed, ks=(3, 4, 5))


def test_multigraph_input_canonicalizes():
    """Duplicate edges and self loops in the input edge list must not
    change any count (exact-once attribution would double-count them if
    canonicalization ever regressed)."""
    clean = [(0, 1), (1, 2), (0, 2), (2, 3), (1, 3), (0, 3)]
    noisy = clean + [(1, 0), (2, 1), (0, 0), (3, 3)] + clean[:3]
    g_clean = from_edges(4, np.asarray(clean, np.int64))
    g_noisy = from_edges(4, np.asarray(noisy, np.int64))
    assert np.array_equal(g_clean.edges, g_noisy.edges)
    for k in (3, 4):
        want = oracle.count_kcliques_brute(g_clean, k)
        assert ebbkc.count(g_noisy, k).count == want
        assert engine_jax.count(g_noisy, k).count == want
