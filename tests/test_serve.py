"""Serving tier: single-request parity vs the plain engines, concurrent
byte-identical determinism, deadline accounting, backpressure, coalescing
stats, and the routed multi-request dispatcher seam.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise
the tier over multi-device dispatch (the CI matrix does both 1 and 4).
"""

import os
import sys
import threading

import numpy as np
import pytest

from conftest import random_graph
from repro.core import engine_jax, listing, pipeline
from repro.core import tiles as tiles_mod
from repro.core.engine_np import Stats
from repro.data import rmat_graph
from repro.runtime.dispatch import Dispatcher, ListDispatcher, Routed
from repro.serve import (
    CliqueService,
    ServiceClosed,
    ServiceOverloaded,
    apply_vertex_filter,
    edf_pick,
    fuse_chunks,
)


def make_graphs():
    rng = np.random.default_rng(77)
    return {
        "a": random_graph(rng, n_lo=24, n_hi=25, p_lo=0.3, p_hi=0.3),
        "b": random_graph(rng, n_lo=30, n_hi=31, p_lo=0.25, p_hi=0.25),
        "c": rmat_graph(5, 8, seed=7),
    }


GRAPHS = make_graphs()


def ref_count(g, k):
    return engine_jax.count(g, k).count


def ref_rows(g, k):
    sink = listing.ArraySink(k)
    listing.stream_cliques(g, k, sink)
    return sink.result()


def service(**kw):
    svc = CliqueService(**kw)
    for name, g in GRAPHS.items():
        svc.register_graph(name, g)
    return svc


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_edf_pick_empty():
    assert edf_pick([]) is None


def test_edf_pick_earliest_deadline_wins():
    assert edf_pick([(5.0, 10, 0), (2.0, 1, 1), (9.0, 99, 2)]) == 1


def test_edf_pick_no_deadline_sorts_last():
    assert edf_pick([(None, 1000, 0), (50.0, 1, 1)]) == 1


def test_edf_pick_lpt_fallback_among_equal_deadlines():
    # no deadlines anywhere: the largest remaining work is picked (LPT)
    assert edf_pick([(None, 10, 0), (None, 30, 1), (None, 20, 2)]) == 1


def test_edf_pick_arrival_tiebreak():
    assert edf_pick([(None, 10, 1), (None, 10, 0)]) == 1  # idx 0 wins


def test_fuse_chunks_concatenates_and_segments():
    g = GRAPHS["c"]
    plan = pipeline.cached_plan(g, "hybrid")
    batches = [b for b in pipeline.stream_batches(plan, 4, batch_size=4)
               if not isinstance(b, tiles_mod.Tile)]
    by_t = {}
    for b in batches:
        by_t.setdefault(b.T, []).append(b)
    same_t = next(bs for bs in by_t.values() if len(bs) >= 2)[:2]
    chunks = [("r0", 0, same_t[0]), ("r1", 3, same_t[1])]
    fused, segments = fuse_chunks(chunks)
    assert fused.B == same_t[0].B + same_t[1].B
    assert [(r, s, a, b) for r, s, a, b, _ in segments] == [
        ("r0", 0, 0, same_t[0].B),
        ("r1", 3, same_t[0].B, fused.B),
    ]
    np.testing.assert_array_equal(
        fused.A, np.concatenate([same_t[0].A, same_t[1].A]))
    np.testing.assert_array_equal(
        fused.verts, np.concatenate([same_t[0].verts, same_t[1].verts]))


def test_apply_vertex_filter():
    rows = np.array([[0, 1, 2], [1, 2, 3], [4, 5, 6]])
    np.testing.assert_array_equal(
        apply_vertex_filter(rows, 1), rows[:2])
    assert apply_vertex_filter(rows[:0], 1).shape[0] == 0


# ---------------------------------------------------------------------------
# single-request parity vs the plain engines
# ---------------------------------------------------------------------------


def test_single_count_matches_engine():
    with service() as svc:
        for name, g in GRAPHS.items():
            for k in (3, 4, 5):
                assert svc.submit(name, k).result(120).count \
                    == ref_count(g, k)


def test_single_list_matches_stream_cliques_exactly():
    with service() as svc:
        for name, g in GRAPHS.items():
            for k in (3, 4):
                got = svc.submit(name, k, "list").result(120).rows
                np.testing.assert_array_equal(got, ref_rows(g, k))


def test_count_closed_forms_k1_k2():
    with service() as svc:
        g = GRAPHS["a"]
        assert svc.submit("a", 1).result(30).count == g.n
        assert svc.submit("a", 2).result(30).count == g.m


def test_vertex_filter_and_max_out_semantics():
    with service() as svc:
        g = GRAPHS["b"]
        ref = ref_rows(g, 4)
        v = int(ref[0, 0])
        want = apply_vertex_filter(ref, v)
        got = svc.submit("b", 4, "list", vertex_filter=v).result(120)
        np.testing.assert_array_equal(got.rows, want)
        # max_out truncates AFTER filtering, in stream order
        got2 = svc.submit("b", 4, "list", vertex_filter=v,
                          max_out=3).result(120)
        np.testing.assert_array_equal(got2.rows, want[:3])


def test_external_sink_delivery():
    with service() as svc:
        g = GRAPHS["a"]
        sink = listing.ArraySink(4)
        res = svc.submit("a", 4, "list", sink=sink).result(120)
        assert res.rows is None  # caller owns the sink
        np.testing.assert_array_equal(sink.result(), ref_rows(g, 4))
        assert res.emitted == ref_rows(g, 4).shape[0]


def test_invalid_requests():
    with service() as svc:
        with pytest.raises(KeyError):
            svc.submit("nope", 4)
        with pytest.raises(ValueError):
            svc.submit("a", 2, "list")  # listing needs k >= 3
        with pytest.raises(ValueError):
            svc.submit("a", 4, "explode")
        with pytest.raises(ValueError):
            svc.submit("a", 4, deadline_s=0.0)


def test_submit_after_close_raises():
    svc = service()
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit("a", 4)


# ---------------------------------------------------------------------------
# concurrency: determinism, coalescing, deadlines, backpressure
# ---------------------------------------------------------------------------

SETTINGS = [
    dict(chunk_tiles=16, fuse_rows=64, async_staging=False),
    dict(chunk_tiles=32, fuse_rows=128, async_staging=True),
    dict(chunk_tiles=64, fuse_rows=256, async_staging=True),
]


@pytest.mark.parametrize("cfg", SETTINGS)
def test_concurrent_burst_byte_identical_to_serial(cfg):
    specs = [(n, k, m) for n in ("a", "b") for k in (4, 5)
             for m in ("count", "list")]
    refs = {}
    for n, k, m in specs:
        g = GRAPHS[n]
        refs[(n, k, m)] = ref_count(g, k) if m == "count" else ref_rows(g, k)
    with service(**cfg) as svc:
        svc.pause()  # admit the whole burst together: maximal interleaving
        tickets = [(s, svc.submit(s[0], s[1], s[2])) for s in specs * 2]
        svc.resume()
        for (n, k, m), t in tickets:
            res = t.result(300)
            if m == "count":
                assert res.count == refs[(n, k, m)]
            else:
                np.testing.assert_array_equal(res.rows, refs[(n, k, m)])


def test_cross_request_coalescing_happens():
    with service(chunk_tiles=16, fuse_rows=128) as svc:
        svc.pause()
        tickets = [svc.submit("b", 4, "list") for _ in range(6)]
        svc.resume()
        want = ref_rows(GRAPHS["b"], 4)
        for t in tickets:
            np.testing.assert_array_equal(t.result(300).rows, want)
        assert svc.stats.cross_request_batches > 0
        assert svc.stats.fused_chunks > svc.stats.fused_batches


def test_deadline_miss_accounting():
    with service() as svc:
        ok = svc.submit("a", 4, deadline_s=120.0).result(120)
        assert ok.deadline_missed is False
        # an impossible deadline: the result is still exact, only flagged
        late = svc.submit("a", 5, deadline_s=1e-4).result(120)
        assert late.deadline_missed is True
        assert late.count == ref_count(GRAPHS["a"], 5)
        assert svc.stats.deadline_missed == 1
        assert svc.stats.completed >= 2


def test_overload_backpressure_sheds_then_recovers():
    svc = service(max_pending=2)
    try:
        svc.pause()  # stop admission so the queue actually fills
        kept = [svc.submit("a", 4), svc.submit("a", 5)]
        with pytest.raises(ServiceOverloaded):
            svc.submit("b", 4, block=False)
        assert svc.stats.rejected == 1
        svc.resume()  # the queued burst still completes exactly
        assert kept[0].result(120).count == ref_count(GRAPHS["a"], 4)
        assert kept[1].result(120).count == ref_count(GRAPHS["a"], 5)
    finally:
        svc.close()


def test_many_clients_many_threads():
    errors = []
    with service() as svc:
        refs = {k: ref_count(GRAPHS["c"], k) for k in (3, 4, 5)}

        def client(i):
            try:
                for k in (3, 4, 5):
                    assert svc.submit("c", k).result(120).count == refs[k]
            except BaseException as exc:  # surfaced after join
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors


# ---------------------------------------------------------------------------
# the routed dispatcher seam (multi-request streams through consume)
# ---------------------------------------------------------------------------


def _routed_stream(plan_k_pairs, *, interleave=True):
    """Interleave each request's packed-batch stream, wrapped in Routed."""
    streams = []
    for rid, (g, k, route) in enumerate(plan_k_pairs):
        plan = pipeline.cached_plan(g, "hybrid")
        items = list(pipeline.stream_batches(plan, k, batch_size=16))
        streams.append([Routed(it, route) for it in items])
    if not interleave:
        for s in streams:
            yield from s
        return
    i = 0
    while any(streams):
        s = streams[i % len(streams)]
        if s:
            yield s.pop(0)
        i += 1


def test_dispatcher_consume_interleaved_routed_counts():
    k = 4
    l = k - 2
    totals = {}

    def mk_route(rid):
        def route(hard, nv, t, f):
            totals[rid] = totals.get(rid, 0) + engine_jax.combine_counts(
                hard, nv, t, f, l, True)
        return route

    def on_spill(tile, route=None):
        c = engine_jax.count_spilled(tile, "hybrid", l, Stats(), 3, True)
        if route is not None:
            # spilled work still belongs to its request
            totals_key = [rid for rid, r in routes.items() if r is route][0]
            totals[totals_key] = totals.get(totals_key, 0) + c

    routes = {0: mk_route(0), 1: mk_route(1)}
    disp = Dispatcher(l, None, et=True)
    stream = _routed_stream([(GRAPHS["a"], k, routes[0]),
                             (GRAPHS["b"], k, routes[1])])
    disp.consume(stream, on_spill=on_spill)
    disp.finish()
    assert totals[0] == ref_count(GRAPHS["a"], k)
    assert totals[1] == ref_count(GRAPHS["b"], k)


def test_list_dispatcher_consume_interleaved_routed_rows():
    k = 4
    l = k - 2
    rows = {0: [], 1: []}

    def mk_route(rid):
        def route(batch, bufs, cnt, ovf):
            out = listing.decode_batch(batch, bufs, cnt, ovf, l, Stats(),
                                       et_t=3)
            rows[rid].append(out)
            return out.shape[0]
        return route

    disp = ListDispatcher(l, None, sink=None, et_t=3)
    stream = _routed_stream([(GRAPHS["a"], k, mk_route(0)),
                             (GRAPHS["b"], k, mk_route(1))])
    disp.consume(stream)
    disp.finish()
    for rid, g in ((0, GRAPHS["a"]), (1, GRAPHS["b"])):
        got = np.concatenate(rows[rid]) if rows[rid] else np.empty((0, k))
        np.testing.assert_array_equal(got, ref_rows(g, k))


def test_dispatcher_unrouted_stream_still_totals():
    # bare TileBatch items keep the classic single-request behavior
    k, l = 4, 2
    g = GRAPHS["a"]
    plan = pipeline.cached_plan(g, "hybrid")
    disp = Dispatcher(l, None, et=True)
    spilled = []
    disp.consume(pipeline.stream_batches(plan, k, batch_size=32),
                 on_spill=lambda t: spilled.append(t))
    assert disp.finish() + sum(
        engine_jax.count_spilled(t, "hybrid", l, Stats(), 3, True)
        for t in spilled) == ref_count(g, k)


# ---------------------------------------------------------------------------
# loadgen API
# ---------------------------------------------------------------------------


def load_loadgen():
    import importlib.util

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "loadgen.py")
    spec = importlib.util.spec_from_file_location("loadgen", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_loadgen_workload_is_seeded_and_mixed():
    lg = load_loadgen()
    w1 = lg.build_workload(["a", "b"], [4, 5], 24, 0.5, 0.5, 10, 100.0, 3)
    w2 = lg.build_workload(["a", "b"], [4, 5], 24, 0.5, 0.5, 10, 100.0, 3)
    assert w1 == w2  # same seed, same multiset
    assert {s["graph"] for s in w1} == {"a", "b"}
    assert {s["mode"] for s in w1} == {"count", "list"}
    assert all(s["deadline_s"] == 0.1 for s in w1)
    w3 = lg.build_workload(["a"], [4], 8, 0.5, 0.5, 10, 100.0, 4)
    assert w3 != w1[:8]


def test_loadgen_summarize_fields():
    lg = load_loadgen()
    rec = lg.summarize("serve", [0.010, 0.020, 0.030, 0.040], 1, 0, 2, 2.0)
    assert rec["completed"] == 4 and rec["rejected"] == 2
    assert rec["requests"] == 6
    assert rec["goodput_rps"] == pytest.approx(1.5)  # (4 - 1 missed) / 2s
    assert rec["throughput_rps"] == pytest.approx(2.0)
    assert rec["miss_rate"] == pytest.approx(0.25)
    assert rec["p50_ms"] == pytest.approx(25.0)
    assert sum(rec["latency_hist"]) == 4


def test_loadgen_end_to_end_serve_smoke(tmp_path):
    lg = load_loadgen()
    out = tmp_path / "lg.json"
    rc = lg.main([
        "--mode", "serve", "--clients", "2", "--requests-per-client", "2",
        "--graphs", "er:16,0.5", "--ks", "4", "--list-frac", "0.5",
        "--warmup", "0", "--json", str(out),
    ])
    assert rc == 0
    import json

    payload = json.loads(out.read_text())
    assert len(payload["records"]) == 1
    rec = payload["records"][0]
    assert rec["mismatches"] == 0 and rec["completed"] == 4
    assert rec["kind"] == "serve_loadgen"
    assert rec["config"]["clients"] == 2
    assert "serve_stats" in rec
