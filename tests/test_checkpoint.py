"""Checkpoint store: atomic commit, resume, GC, elastic restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (gc_checkpoints, latest_step,
                              restore_checkpoint, save_checkpoint)


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
            "count": jnp.int32(7)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 5, t, pipeline_state={"step": 5},
                    metadata={"note": "x"})
    got = restore_checkpoint(d, t)
    assert got["step"] == 5
    assert got["pipeline"] == {"step": 5}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got["tree"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    t = tree()
    for s in (1, 3, 7, 9):
        save_checkpoint(d, s, t)
    assert latest_step(d) == 9
    gc_checkpoints(d, keep=2)
    assert latest_step(d) == 9
    assert restore_checkpoint(d, t, step=7) is not None \
        and os.path.isdir(os.path.join(d, "step_0000000007"))
    assert not os.path.isdir(os.path.join(d, "step_0000000001"))


def test_uncommitted_ignored(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 2, t)
    # simulate a crash mid-write at step 4: directory without COMMITTED
    os.makedirs(os.path.join(d, "step_0000000004"))
    assert latest_step(d) == 2
    got = restore_checkpoint(d, t)
    assert got["step"] == 2


def test_elastic_restore_with_sharding(tmp_path):
    """Restore under explicit (new) shardings -- the elastic-rescale path."""
    from jax.sharding import NamedSharding, PartitionSpec as P, Mesh
    d = str(tmp_path)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(d, 1, t)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got = restore_checkpoint(d, t, shardings=sh)
    assert got["tree"]["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["tree"]["w"]),
                                  np.asarray(t["w"]))
