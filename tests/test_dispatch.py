"""Multi-device dispatch: N-device vs 1-device vs host-oracle parity,
scheduler-bin -> device mapping, double-buffered staging equivalence.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise
the real multi-device path (the CI matrix does); on a 1-device host every
test still passes through the graceful single-device fallback.
"""

import jax
import numpy as np
import pytest

from conftest import random_graph
from repro.core import ebbkc, engine_jax, pipeline
from repro.core.engine_np import Stats
from repro.data import erdos_renyi, rmat_graph
from repro.launch.mesh import make_local_mesh
from repro.runtime import dispatch as dsp

N_DEV = jax.device_count()


def dispatch_suite():
    return {
        "rmat": rmat_graph(8, 4, seed=7),
        "er": erdos_renyi(100, 0.12, seed=1),
    }


def test_resolve_devices_fallback():
    avail = jax.devices()
    assert dsp.resolve_devices(None) == list(avail)
    assert dsp.resolve_devices("all") == list(avail)
    # asking for more devices than exist degrades gracefully, never errors
    assert dsp.resolve_devices(len(avail) + 7) == list(avail)
    assert dsp.resolve_devices(1) == [avail[0]]
    assert dsp.resolve_devices([avail[0]]) == [avail[0]]
    with pytest.raises(ValueError):
        dsp.resolve_devices(0)
    with pytest.raises(ValueError):
        dsp.resolve_devices([])


def test_dispatcher_requires_l_ge_1():
    with pytest.raises(ValueError):
        dsp.Dispatcher(0)


@pytest.mark.parametrize("order", ["truss", "hybrid", "color"])
def test_multi_device_count_parity(order):
    """devices=N == devices=1 == host oracle for every graph/k/order."""
    for name, g in dispatch_suite().items():
        for k in range(3, 7):
            ref = ebbkc.count(g, k, order=order).count
            one = engine_jax.count(g, k, order=order, devices=1, interpret=True)
            many = engine_jax.count(g, k, order=order, devices=N_DEV, interpret=True)
            assert one.count == ref, (name, k, order)
            assert many.count == ref, (name, k, order)
            assert many.tiles == one.tiles, (name, k, order)


def test_scheduler_bins_map_onto_distinct_devices():
    g = rmat_graph(8, 4, seed=7)
    k = 4
    batches = [
        b
        for b in pipeline.stream_batches(g, k, batch_size=32)
        if isinstance(b, pipeline.TileBatch)
    ]
    assert len(batches) >= 4
    stats = Stats()
    total, info = dsp.dispatch_scheduled(
        batches, k - 2, devices=N_DEV, interpret=True, stats=stats
    )
    assert total == ebbkc.count(g, k).count
    # every batch got a realized placement on a real device ordinal
    assert len(info["placements"]) == len(batches)
    assert set(info["placements"]) <= set(range(info["n_devices"]))
    # the LPT bins were honored: batch j of bin d ran on device d
    placed = {}
    for d, bin_ids in enumerate(info["device_bins"]):
        for bi in bin_ids:
            placed[bi] = d
    # placements are recorded in submission order; reconstruct it
    import itertools

    submitted = []
    for wave in itertools.zip_longest(*info["device_bins"]):
        for d, bi in enumerate(wave):
            if bi is not None:
                submitted.append((bi, d))
    for (bi, d), got in zip(submitted, info["placements"]):
        assert placed[bi] == d == got
    # with >1 device and >=n_dev batches, more than one device does work
    if info["n_devices"] > 1:
        assert len(set(info["placements"])) > 1
        assert len(stats.device_tiles) > 1
    assert sum(stats.device_tiles.values()) == sum(b.B for b in batches)


def test_device_stats_accounting():
    g = rmat_graph(8, 4, seed=7)
    k = 5
    r = engine_jax.count(g, k, devices=N_DEV, interpret=True)
    assert sum(r.stats.device_tiles.values()) == r.tiles - r.stats.spilled_tiles
    assert set(r.stats.device_flops) == set(r.stats.device_tiles)
    for d, fl in r.stats.device_flops.items():
        assert fl > 0 and fl % r.stats.device_tiles[d] == 0
    assert r.stats.staging_overlap_s >= 0.0


@pytest.mark.parametrize("k", [3, 5])
def test_async_staging_matches_synchronous(k):
    """Double-buffered staging produces the same totals as synchronous."""
    for name, g in dispatch_suite().items():
        a = engine_jax.count(g, k, devices=N_DEV, interpret=True, async_staging=True)
        b = engine_jax.count(g, k, devices=N_DEV, interpret=True, async_staging=False)
        assert a.count == b.count, (name, k)
        assert a.tiles == b.tiles, (name, k)
        assert b.stats.staging_overlap_s == 0.0


def test_mesh_shard_map_path():
    g = rmat_graph(8, 4, seed=7)
    mesh = make_local_mesh((N_DEV, 1), axes=("data", "model"))
    for k in (3, 5):
        ref = ebbkc.count(g, k).count
        batches = [
            b
            for b in pipeline.stream_batches(g, k, batch_size=64)
            if isinstance(b, pipeline.TileBatch)
        ]
        stats = Stats()
        total, info = dsp.dispatch_scheduled(
            batches, k - 2, mesh=mesh, interpret=True, stats=stats
        )
        assert total == ref, k
        assert info["n_devices"] == N_DEV
        # sharded batches spread tiles across every shard
        if N_DEV > 1:
            assert len(stats.device_tiles) == N_DEV


def test_pad_rows_is_count_neutral():
    """Zero-cand padding rows contribute exactly 0 for every l >= 1."""
    rng = np.random.default_rng(2)
    g = random_graph(rng, n_lo=14, n_hi=20, p_lo=0.5, p_hi=0.8)
    binned = engine_jax.bin_tiles(g, 4, spill=[])
    T, packed = next(iter(binned.items()))
    for l in (1, 2, 3, 4):
        base = engine_jax.combine_counts(
            *engine_jax.count_packed(packed.A, packed.cand, l, interpret=True),
            l,
            True,
        )
        A = dsp._pad_rows(packed.A, packed.A.shape[0] + 3)
        cand = dsp._pad_rows(packed.cand, packed.cand.shape[0] + 3)
        assert A.shape[0] > packed.A.shape[0]
        padded = engine_jax.combine_counts(
            *engine_jax.count_packed(A, cand, l, interpret=True), l, True
        )
        assert padded == base, l


def _run_list_dispatcher(batches, l, **kwargs):
    from repro.core import listing

    sink = listing.ArraySink(l + 2)
    stats = Stats()
    disp = dsp.ListDispatcher(l, sink=sink, stats=stats, **kwargs)
    for b in batches:
        disp.submit(b)
    disp.finish()
    return sink.result(), stats


@pytest.mark.parametrize("k", [4, 5])
def test_list_dispatcher_sink_order_deterministic(k):
    """The pipelined count-pass/list-kernel/harvest overlap must keep sink
    order exactly the submission (batch) order: every device count,
    staging mode, and capacity mode yields the SAME array, byte for byte
    (not merely the same set)."""
    g = rmat_graph(8, 4, seed=7)
    batches = [
        b
        for b in pipeline.stream_batches(g, k, batch_size=16)
        if isinstance(b, pipeline.TileBatch)
    ]
    assert len(batches) >= 4
    base, base_stats = _run_list_dispatcher(batches, k - 2, devices=1)
    assert base.shape[0] == ebbkc.count(g, k).count
    for kwargs in (
        dict(devices=N_DEV),
        dict(devices=N_DEV, async_staging=False),
        dict(devices=N_DEV, max_inflight=1),
        dict(devices=N_DEV, capacity=8),  # fixed capacity: no count pass
        dict(devices=N_DEV, capacity=2),  # overflow -> host re-list path
    ):
        got, stats = _run_list_dispatcher(batches, k - 2, **kwargs)
        assert np.array_equal(got, base), kwargs
    if N_DEV > 1:
        got, stats = _run_list_dispatcher(batches, k - 2, devices=N_DEV)
        assert len(stats.device_tiles) > 1  # work actually spread


def test_list_dispatcher_overlaps_count_pass():
    """submit() must not serialize on the emit-sizing count pass: batches
    become pending and are promoted FIFO (possibly later), and everything
    drains at finish()."""
    g = rmat_graph(8, 4, seed=7)
    k = 4
    batches = [
        b
        for b in pipeline.stream_batches(g, k, batch_size=8)
        if isinstance(b, pipeline.TileBatch)
    ]
    from repro.core import listing

    sink = listing.ArraySink(k)
    disp = dsp.ListDispatcher(k - 2, devices=N_DEV, sink=sink, stats=Stats())
    for b in batches:
        disp.submit(b)
    # the pipelined window holds work in *some* stage, bounded by the
    # in-flight cap; nothing is lost at drain time
    assert (
        len(disp._pending) + len(disp._inflight)
        <= disp.max_inflight * disp.n_devices + 1
    )
    disp.finish()
    assert len(disp._pending) == 0 and len(disp._inflight) == 0
    assert sink.accepted == ebbkc.count(g, k).count


# spill x multi-device interaction is covered by
# tests/test_pipeline.py::test_spill_interacts_with_multi_device_dispatch


def test_consume_drives_both_dispatchers():
    """Both dispatchers share one stream-consumption point: packed batches
    are submitted, spill tiles routed to on_spill, tiles/max_tile
    accounted -- and a spill without a handler is an error."""
    from repro.core import listing

    g = rmat_graph(8, 4, seed=7)
    k = 4
    ref = ebbkc.count(g, k).count
    disp = dsp.Dispatcher(k - 2, devices=N_DEV, stats=Stats())
    ntiles, max_tile = disp.consume(
        pipeline.stream_batches(g, k, batch_size=32, pack_workers=2))
    assert disp.finish() == ref
    assert ntiles == sum(b.B for b in pipeline.stream_batches(g, k))
    assert max_tile in pipeline.BINS
    # oversize tiles demand a spill handler
    dense = erdos_renyi(44, 0.97, seed=2)
    disp2 = dsp.Dispatcher(2, devices=1, stats=Stats())
    with pytest.raises(ValueError, match="on_spill"):
        disp2.consume(pipeline.stream_batches(dense, 4, bins=(32,)))
    disp2.finish()
    sink = listing.ArraySink(k)
    ldisp = dsp.ListDispatcher(k - 2, devices=N_DEV, sink=sink,
                               stats=Stats())
    ldisp.consume(pipeline.stream_batches(g, k, batch_size=32,
                                          pack_workers=2))
    ldisp.finish()
    assert sink.accepted == ref


def test_plan_reuse_across_device_counts():
    """One PipelinePlan serves queries at any device count (the serving
    scenario: preprocessing paid once, dispatch chosen per query)."""
    g = rmat_graph(8, 4, seed=7)
    plan = pipeline.build_plan(g, order="hybrid")
    ref = {k: ebbkc.count(g, k, plan=plan).count for k in (4, 5)}
    for devices in (1, N_DEV, "all"):
        for k in (4, 5):
            r = engine_jax.count(g, k, plan=plan, devices=devices, interpret=True)
            assert r.count == ref[k], (devices, k)
