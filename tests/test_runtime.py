"""Fault tolerance: crash/restart reproducibility, straggler watchdog,
clique scheduler balance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import LMDataPipeline
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import TrainLoop, TrainLoopConfig, balanced_bins
from repro.runtime.clique_scheduler import schedule_tiles, tile_cost
from repro import configs


def make_training(ckpt_dir):
    cfg = configs.get("granite-3-8b").reduced
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3)

    @jax.jit
    def step(params, opt, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, g = jax.value_and_grad(
            lambda p: tr.loss_fn(p, batch, cfg))(params)
        params, opt, m = adamw_update(g, opt, params, ocfg)
        return params, opt, {"loss": loss, **m}

    pipe = LMDataPipeline(vocab=cfg.vocab, batch=2, seq_len=16)
    return step, params, opt, pipe


def test_crash_resume_bitwise(tmp_path):
    """Kill at step 7, restart, final params match an uninterrupted run."""
    d = str(tmp_path / "ck")
    # uninterrupted reference
    step, params, opt, pipe = make_training(None)
    loop = TrainLoop(TrainLoopConfig(total_steps=10, checkpoint_dir=None),
                     step, params, opt, pipe)
    loop.run()
    ref = loop.params

    # crashing run: checkpoint every 2, injected failure at step 7
    step2, params2, opt2, pipe2 = make_training(d)
    loop2 = TrainLoop(
        TrainLoopConfig(total_steps=10, checkpoint_dir=d,
                        checkpoint_every=2, fail_at_step=7),
        step2, params2, opt2, pipe2)
    with pytest.raises(RuntimeError):
        loop2.run()
    # restart: auto-resumes from step 6 and replays the exact stream
    step3, params3, opt3, pipe3 = make_training(d)
    loop3 = TrainLoop(
        TrainLoopConfig(total_steps=10, checkpoint_dir=d,
                        checkpoint_every=2),
        step3, params3, opt3, pipe3)
    assert loop3.step == 6
    loop3.run()
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(loop3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=200),
       st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_lpt_balance(costs, n_bins):
    bins, loads = balanced_bins(costs, n_bins)
    # every item assigned exactly once
    all_items = sorted(i for b in bins for i in b)
    assert all_items == list(range(len(costs)))
    # LPT guarantee: max load <= mean + max_item
    assert loads.max() <= loads.sum() / n_bins + max(costs) + 1e-9


def test_schedule_tiles_balance():
    class T:
        def __init__(self, s, e):
            self.s, self.nedges = s, e
    rng = np.random.default_rng(0)
    tiles = [T(int(rng.integers(2, 64)), int(rng.integers(1, 500)))
             for _ in range(500)]
    device_bins, stats = schedule_tiles(tiles, l=3, n_devices=16)
    assert sorted(i for b in device_bins for i in b) == list(range(500))
    assert stats["max_over_mean"] < 1.2  # tight static balance


def test_tile_cost_monotone():
    assert tile_cost(10, 45, 4) >= tile_cost(10, 45, 3)
    assert tile_cost(30, 400, 5) > tile_cost(10, 45, 5)
