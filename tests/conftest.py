import os
import sys

# smoke tests and benches must see ONE device (the 512-device override is
# confined to launch/dryrun.py per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core import graph as graph_mod


def random_graph(rng, n_lo=5, n_hi=18, p_lo=0.15, p_hi=0.8):
    n = int(rng.integers(n_lo, n_hi))
    p = float(rng.uniform(p_lo, p_hi))
    mask = rng.random((n, n)) < p
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if mask[i, j]]
    return graph_mod.from_edges(n, edges)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
