import os
import sys

# smoke tests and benches must see ONE device (the 512-device override is
# confined to launch/dryrun.py per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_shim():
    """Minimal deterministic stand-in so the suite collects without
    ``hypothesis`` installed (it is optional, see requirements-dev.txt).

    Supports the subset the tests use: ``@given(st.integers/floats/lists)``
    stacked with ``@settings(max_examples=..., deadline=...)``.  Each
    example is drawn from a fixed-seed PRNG, so runs are reproducible (no
    shrinking, no database).
    """
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd):
            return self._draw(rnd)

    st = types.ModuleType("hypothesis.strategies")

    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def lists(elements, min_size=0, max_size=10):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.example(rnd) for _ in range(n)]
        return _Strategy(draw)

    st.integers, st.floats, st.lists = integers, floats, lists

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", None) \
                    or getattr(fn, "_shim_max_examples", 20)
                n = min(n, int(os.environ.get("HYPOTHESIS_SHIM_MAX", n)))
                rnd = random.Random(0xEBB)
                for _ in range(n):
                    fn(*args, *(s.example(rnd) for s in strats), **kwargs)
            # deliberately NOT functools.wraps: pytest must not see the
            # wrapped function's strategy parameters as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.strategies = st
    hyp.given = given
    hyp.settings = settings
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_shim()

import numpy as np
import pytest

from repro.core import graph as graph_mod


def random_graph(rng, n_lo=5, n_hi=18, p_lo=0.15, p_hi=0.8):
    n = int(rng.integers(n_lo, n_hi))
    p = float(rng.uniform(p_lo, p_hi))
    mask = rng.random((n, n)) < p
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if mask[i, j]]
    return graph_mod.from_edges(n, edges)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
