"""Unified tile pipeline: parity with the Python reference oracle,
streaming-batcher invariants, scheduler mapping, oversize spill."""
import numpy as np
import pytest

from repro.core import ebbkc, engine_jax, pipeline
from repro.core import tiles as tiles_mod
from repro.data import erdos_renyi, planted_cliques, rmat_graph
from repro.runtime.clique_scheduler import schedule_batches, schedule_tiles

from conftest import random_graph


def parity_suite():
    return {
        "rmat": rmat_graph(8, 4, seed=7),
        "er": erdos_renyi(120, 0.12, seed=1),
        "plant": planted_cliques(150, 4, 9, p_noise=0.02, seed=5),
    }


def tiles_equal(a, b):
    return (a.anchor == b.anchor and np.array_equal(a.verts, b.verts)
            and a.rows == b.rows and a.nedges == b.nedges
            and a.colors == b.colors and a.edges_ranked == b.edges_ranked)


@pytest.mark.parametrize("mode", ["truss", "color", "hybrid"])
def test_iter_tiles_matches_reference(mode):
    for name, g in parity_suite().items():
        for k in range(3, 8):
            ref = list(tiles_mod.edge_tiles(g, k, mode=mode))
            got = list(pipeline.iter_tiles(g, k, mode=mode))
            assert len(ref) == len(got), (name, k, mode)
            for a, b in zip(ref, got):
                assert tiles_equal(a, b), (name, k, mode, a.anchor)


def test_color_mode_rule2_parity():
    g = parity_suite()["plant"]
    for use_rule2 in (True, False):
        ref = list(tiles_mod.edge_tiles(g, 5, mode="color",
                                        use_rule2=use_rule2))
        got = list(pipeline.iter_tiles(g, 5, mode="color",
                                       use_rule2=use_rule2))
        assert len(ref) == len(got)
        for a, b in zip(ref, got):
            assert tiles_equal(a, b)


@pytest.mark.parametrize("mode", ["truss", "color", "hybrid"])
def test_packed_batches_byte_identical(mode):
    """Streamed batches, concatenated per bin, match the reference
    extractor + packer byte for byte."""
    for name, g in parity_suite().items():
        k = 5
        binned = {}
        for t in tiles_mod.edge_tiles(g, k, mode=mode):
            T = next(b for b in pipeline.BINS if t.s <= b)
            binned.setdefault(T, []).append(t)
        ref = {T: engine_jax.pack_tiles(ts, T)
               for T, ts in sorted(binned.items())}
        got = {}
        for item in pipeline.stream_batches(g, k, order=mode, batch_size=64):
            assert isinstance(item, pipeline.TileBatch)
            got.setdefault(item.T, []).append(item)
        assert sorted(got) == sorted(ref), (name, mode)
        for T in ref:
            A = np.concatenate([b.A for b in got[T]])
            cand = np.concatenate([b.cand for b in got[T]])
            assert np.array_equal(A, ref[T].A), (name, mode, T)
            assert np.array_equal(cand, ref[T].cand), (name, mode, T)


def test_batcher_shape_and_coverage_invariants(rng):
    g = random_graph(rng, n_lo=25, n_hi=35, p_lo=0.4, p_hi=0.7)
    k = 4
    n_ref = sum(1 for _ in tiles_mod.edge_tiles(g, k, mode="hybrid"))
    seen = 0
    for item in pipeline.stream_batches(g, k, batch_size=8):
        assert isinstance(item, pipeline.TileBatch)
        B, T, W = item.A.shape
        assert B <= 8 and T in pipeline.BINS and W == T // 32
        assert item.cand.shape == (B, W)
        assert item.sizes.shape == (B,) and item.nedges.shape == (B,)
        assert item.anchors.shape == (B, 2)
        assert (item.sizes <= T).all() and (item.sizes > 0).all()
        # decode table for the emission subsystem: valid global vertex
        # ids in every live slot, zero padding beyond sizes
        assert item.verts.shape == (B, T)
        live = np.arange(T)[None, :] < item.sizes[:, None]
        assert ((item.verts >= 0) & (item.verts < g.n))[live].all()
        assert (item.verts[~live] == 0).all()
        seen += B
    assert seen == n_ref


def test_plan_reuse_skips_preprocessing(rng):
    g = random_graph(rng, n_lo=20, n_hi=30, p_lo=0.4, p_hi=0.7)
    plan = pipeline.build_plan(g, order="hybrid")
    table_before = plan.table("hybrid")
    r1 = ebbkc.count(g, 4, plan=plan)
    r2 = ebbkc.count(g, 5, plan=plan)
    assert plan.table("hybrid") is table_before  # cached, not rebuilt
    assert r1.count == ebbkc.count(g, 4).count
    assert r2.count == ebbkc.count(g, 5).count


@pytest.mark.parametrize("mode", ["truss", "color", "hybrid"])
def test_parallel_producer_matches_serial(mode):
    """pack_workers > 0 yields the byte-identical batch stream (content
    AND order), spill tiles included, for every ordering."""
    g = rmat_graph(8, 4, seed=7)
    for bins, batch_size in (((32, 64, 128, 256), 16), ((32,), 8)):
        ref = list(pipeline.stream_batches(g, 5, order=mode, bins=bins,
                                           batch_size=batch_size))
        got = list(pipeline.stream_batches(g, 5, order=mode, bins=bins,
                                           batch_size=batch_size,
                                           pack_workers=3, prefetch=4))
        assert len(ref) == len(got)
        for a, b in zip(ref, got):
            assert type(a) is type(b)
            if isinstance(a, pipeline.TileBatch):
                assert np.array_equal(a.A, b.A)
                assert np.array_equal(a.cand, b.cand)
                assert np.array_equal(a.verts, b.verts)
                assert np.array_equal(a.anchors, b.anchors)
            else:
                assert a.anchor == b.anchor and a.rows == b.rows


def test_parallel_producer_stats_and_timings():
    from repro.core.engine_np import Stats

    g = rmat_graph(8, 4, seed=7)
    stats = Stats()
    timings = {}
    n = sum(1 for _ in pipeline.stream_batches(
        g, 5, batch_size=16, pack_workers=2, prefetch=3,
        timings=timings, stats=stats))
    assert n > 1
    assert stats.pack_workers == 2
    assert stats.frontend_s > 0.0
    assert stats.frontend_s >= timings.get("pack", 0.0)
    assert 0.0 < stats.pack_queue_occupancy <= 1.0
    assert 1 <= stats.pack_queue_peak <= 3
    # serial path reports workers=0 and no queue
    s2 = Stats()
    list(pipeline.stream_batches(g, 5, batch_size=16, pack_workers=0,
                                 stats=s2))
    assert s2.pack_workers == 0 and s2.pack_queue_peak == 0
    assert s2.frontend_s > 0.0


def test_plan_cache_warm_queries_skip_decomposition(monkeypatch):
    """Acceptance: a warm plan-cached query never reaches the O(delta*m)
    truss decomposition, and Stats says so."""
    from repro.core import engine_jax as ej

    g = rmat_graph(7, 4, seed=3)
    ref4 = ebbkc.count(g, 4).count
    ref5 = ebbkc.count(g, 5).count
    pipeline.clear_plan_cache()
    r1 = ej.count(g, 4)
    assert not r1.stats.plan_cache_hit
    assert r1.stats.plan_build_s > 0.0
    assert r1.count == ref4

    def boom(*a, **kw):  # pragma: no cover - must never run
        raise AssertionError("truss decomposition re-ran on a warm query")

    monkeypatch.setattr(pipeline, "truss_decomposition", boom)
    # warm: same graph content, different k and even a different Graph
    # object (the key is content-addressed)
    r2 = ej.count(g, 5)
    assert r2.stats.plan_cache_hit and r2.stats.plan_build_s == 0.0
    assert r2.count == ref5
    g2 = rmat_graph(7, 4, seed=3)
    r3 = ej.count(g2, 4, devices=1)
    assert r3.stats.plan_cache_hit and r3.count == ref4
    # truss-order queries share the hybrid family table
    r4 = ej.count(g, 4, order="truss")
    assert r4.stats.plan_cache_hit
    # a cold cache really does rebuild (the tripwire fires)
    with pytest.raises(AssertionError, match="re-ran"):
        pipeline.clear_plan_cache()
        ej.count(g, 4)


def test_plan_save_load_roundtrip(tmp_path):
    g = rmat_graph(7, 4, seed=3)
    pipeline.clear_plan_cache()
    plan = pipeline.build_plan(g, order="hybrid")
    plan.table("color")  # persist both families
    path = str(tmp_path / "plan")
    pipeline.save_plan(plan, path)
    got = pipeline.load_plan(path)
    assert got is not None
    assert got.g.n == g.n and np.array_equal(got.g.edges, g.edges)
    assert got.td.tau == plan.td.tau  # decomposition restored, not rebuilt
    for family in ("truss", "color"):
        a, b = plan.table(family), got.table(family)
        for f in ("edge_id", "anchors", "offsets", "verts", "thresh",
                  "ekeys"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (family, f)
    for k in (4, 5):
        assert ebbkc.count(g, k, plan=got).count == ebbkc.count(g, k).count
    assert pipeline.load_plan(str(tmp_path / "nope")) is None


def test_plan_cache_dir_warms_across_processes(tmp_path, monkeypatch):
    """cache_dir simulates the restarted-process path: clear the
    in-process cache, reload from disk, decomposition still skipped."""
    from repro.core.engine_np import Stats

    g = rmat_graph(7, 4, seed=9)
    ref = ebbkc.count(g, 4).count
    cache = str(tmp_path / "plans")
    pipeline.clear_plan_cache()
    s1 = Stats()
    pipeline.cached_plan(g, "hybrid", cache_dir=cache, stats=s1)
    assert not s1.plan_cache_hit and s1.plan_build_s > 0.0
    pipeline.clear_plan_cache()  # "new process"
    monkeypatch.setattr(
        pipeline, "truss_decomposition",
        lambda *a, **kw: (_ for _ in ()).throw(AssertionError("re-ran")))
    s2 = Stats()
    plan = pipeline.cached_plan(g, "hybrid", cache_dir=cache, stats=s2)
    assert s2.plan_cache_hit and s2.plan_build_s == 0.0
    assert ebbkc.count(g, 4, plan=plan).count == ref


def test_plan_key_no_vertex_count_aliasing():
    """Satellite regression: the cache key must fold in the full graph
    identity, not just the edge bytes + family.

    Two graphs with byte-identical edge lists but different ``n``
    (trailing isolated vertices) are *different plans*: edge keys are
    ``u * n + v``, so a plan built for the smaller vertex set mis-probes
    adjacency when served for the larger graph.  The pre-fix edges-only
    key collides for the twins; ``pipeline.plan_key`` must not -- and
    serving the aliased plan must be demonstrably wrong, so this test
    fails loudly if the key ever regresses.
    """
    import hashlib

    from repro.core.graph import from_edges

    s = 5
    edges = np.argwhere(np.triu(np.ones((s, s), bool), 1)).astype(np.int64)
    g_small = from_edges(s, edges)        # K5, n = 5
    g_big = from_edges(s + 3, edges)      # K5 + 3 isolated vertices
    assert np.array_equal(g_small.edges, g_big.edges)

    def prefix_key(g, order):  # the pre-fix key: family + edge bytes only
        family = "color" if order == "color" else "truss"
        h = hashlib.sha256()
        h.update(f"plan-v{pipeline.PLAN_FORMAT}:{family}:".encode())
        h.update(np.ascontiguousarray(g.edges).tobytes())
        return h.hexdigest()[:24]

    # the old key aliases the twins; the fixed key separates them
    assert prefix_key(g_small, "hybrid") == prefix_key(g_big, "hybrid")
    assert pipeline.plan_key(g_small, "hybrid") != \
        pipeline.plan_key(g_big, "hybrid")
    # ...and the canonicalization contract is part of the key, so a
    # future from_edges change re-keys instead of aliasing stale plans
    assert pipeline.PLAN_CANON in ("dedup-lexsorted-v1",)

    # the aliasing is not harmless: a plan is only substitutable for the
    # graph identity it was keyed under.  The dynamic-graph update path
    # mutates vertices that exist only in the big twin; handed the
    # aliased small-n plan it hard-fails, while the correctly keyed
    # plan for the same request repairs cleanly and stays exact
    from repro.core.graph import apply_edge_batch
    from repro.delta import repair_plan

    g_mut = apply_edge_batch(g_big, insert=[(0, s), (1, s), (0, s + 1)])
    plan_small = pipeline.build_plan(g_small, "hybrid")
    with pytest.raises(ValueError):
        repair_plan(plan_small, g_mut, "hybrid")
    pipeline.clear_plan_cache()
    plan_big = pipeline.cached_plan(g_big, "hybrid")
    assert plan_big.g.n == g_big.n  # correct key -> correct identity
    repaired, _ = repair_plan(plan_big, g_mut, "hybrid",
                              churn_threshold=1.1)
    for k in (3, 4):
        assert ebbkc.count(g_mut, k, plan=repaired).count == \
            ebbkc.count(g_mut, k).count


def test_plan_cache_single_flight_race():
    """Satellite regression: two threads racing a cold key must elect
    exactly one builder -- the loser blocks on the latch and reports a
    cache hit with zero build time (the pre-fix path double-built and
    the loser's insert clobbered the winner's published plan)."""
    import threading

    from repro.core.engine_np import Stats

    g = rmat_graph(8, 4, seed=21)
    pipeline.clear_plan_cache()
    barrier = threading.Barrier(2)
    stats = [Stats(), Stats()]
    plans = [None, None]
    errs = []

    def worker(i):
        try:
            barrier.wait(timeout=30)
            plans[i] = pipeline.cached_plan(g, "hybrid", stats=stats[i])
        except Exception as exc:  # pragma: no cover - failure reporting
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errs
    assert plans[0] is plans[1]  # one published plan object, shared
    built = [s for s in stats if s.plan_build_s > 0.0]
    hits = [s for s in stats if s.plan_cache_hit]
    assert len(built) == 1 and len(hits) == 1
    assert hits[0] is not built[0]
    assert ebbkc.count(g, 4, plan=plans[0]).count == ebbkc.count(g, 4).count


def test_scheduler_batches_partition(rng):
    g = random_graph(rng, n_lo=25, n_hi=35, p_lo=0.5, p_hi=0.8)
    batches = [b for b in pipeline.stream_batches(g, 4, batch_size=4)
               if isinstance(b, pipeline.TileBatch)]
    assert len(batches) > 1
    device_bins, stats = schedule_batches(batches, l=2, n_devices=3)
    flat = sorted(i for b in device_bins for i in b)
    # every packed batch lands in exactly one device bin
    assert flat == list(range(len(batches)))
    assert stats["device_loads"].shape == (3,)
    # schedule_tiles consumes the batch's per-tile metadata directly
    bins, st = schedule_tiles(batches[0], l=2, n_devices=2)
    assert sorted(i for b in bins for i in b) == list(range(batches[0].B))


def test_oversize_tiles_spill_to_host(rng):
    g = random_graph(rng, n_lo=42, n_hi=48, p_lo=0.96, p_hi=0.99)
    k = 4
    items = list(pipeline.stream_batches(g, k, bins=(32,)))
    spilled = [t for t in items if isinstance(t, tiles_mod.Tile)]
    assert spilled, "expected tiles wider than the 32-bin"
    ref = ebbkc.count(g, k).count
    r = engine_jax.count(g, k, interpret=True, bins=(32,))
    assert r.count == ref
    assert r.stats.spilled_tiles == len(spilled)
    # every spill is recorded exactly once, with its width, so host-
    # recursion work stays attributable separately from device batches
    assert sorted(r.stats.spill_sizes) == sorted(t.s for t in spilled)
    assert all(s > 32 for s in r.stats.spill_sizes)
    # without a spill list the compatibility binner keeps the old behavior
    with pytest.raises(ValueError):
        engine_jax.bin_tiles(g, k, bins=(32,))
    spill = []
    binned = engine_jax.bin_tiles(g, k, spill=spill, bins=(32,))
    assert len(spill) == len(spilled)
    assert sum(p.A.shape[0] for p in binned.values()) + len(spill) \
        == sum(1 for _ in tiles_mod.edge_tiles(g, k, mode="hybrid"))


def test_spill_interacts_with_multi_device_dispatch(rng):
    """Spill + dispatch: oversize tiles go to the host recursion exactly
    once while the packed remainder shards across all local devices, and
    the combined count still matches the host oracle."""
    import jax

    g = random_graph(rng, n_lo=42, n_hi=48, p_lo=0.96, p_hi=0.99)
    k = 4
    ref = ebbkc.count(g, k).count
    n_dev = jax.device_count()
    r = engine_jax.count(g, k, interpret=True, bins=(32,), devices=n_dev)
    assert r.count == ref
    assert r.stats.spilled_tiles == len(r.stats.spill_sizes) > 0
    assert all(s > 32 for s in r.stats.spill_sizes)
    # device accounting covers exactly the non-spilled tiles
    assert sum(r.stats.device_tiles.values()) \
        == r.tiles - r.stats.spilled_tiles
    # spilled work never lands in the device accounting
    assert all(d in range(n_dev) for d in r.stats.device_tiles)


def test_list_cliques_max_out_exact(rng):
    g = random_graph(rng, n_lo=16, n_hi=20, p_lo=0.6, p_hi=0.9)
    k = 4
    full, _ = ebbkc.list_cliques(g, k)
    assert len(full) > 7
    for cap in (0, 1, 3, 7, len(full), len(full) + 5):
        got, _ = ebbkc.list_cliques(g, k, max_out=cap)
        assert got.shape == (min(cap, len(full)), k)
        as_set = {tuple(r) for r in full.tolist()}
        assert all(tuple(r) in as_set for r in got.tolist())
    # the k <= 2 shortcuts honor the cap too
    got1, _ = ebbkc.list_cliques(g, 1, max_out=3)
    assert got1.shape == (3, 1)
    got2, _ = ebbkc.list_cliques(g, 2, max_out=3)
    assert got2.shape == (3, 2)
