"""Backend registry parity suite: lax vs pallas-interpret vs jnp-ref vs
host oracle vs brute force, at the kernel, engine, and API layers.

The acceptance bar is *array equality*, not set equality: the compiled lax
backend must fill byte-identical (buffer, count, overflow) triples and
byte-identical decoded clique arrays -- zero padding included -- so any
caller can flip backends without re-validating downstream code.
"""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import ebbkc, oracle
from repro.core.bitops import pack_mask, pack_rows
from repro.kernels import lax_backend, ops, ref


def packed_tiles(rng, B, T, n_lo=4, n_hi=16, p_lo=0.3, p_hi=0.9):
    As, cands, gs = [], [], []
    for _ in range(B):
        g = random_graph(rng, n_lo=n_lo, n_hi=min(T, n_hi), p_lo=p_lo,
                         p_hi=p_hi)
        rows = [0] * g.n
        for u, v in g.edges.tolist():
            rows[u] |= 1 << v
            rows[v] |= 1 << u
        As.append(pack_rows(rows, T))
        cands.append(pack_mask((1 << g.n) - 1, T))
        gs.append(g)
    return np.stack(As), np.stack(cands), gs


def crafted_triangle_tiles(T=32):
    """Tiles exercising the lifted l'==3 base case: zero, one, and many
    triangles, plus an empty candidate set."""
    specs = [
        ("star", 6, [(0, i) for i in range(1, 6)]),            # 0 triangles
        ("c4", 4, [(0, 1), (1, 2), (2, 3), (3, 0)]),           # 0 triangles
        ("tri", 5, [(0, 1), (1, 2), (0, 2), (3, 4)]),          # 1 triangle
        ("k6", 6, [(i, j) for i in range(6) for j in range(i + 1, 6)]),
        ("empty", 3, []),
        ("two-tri", 6, [(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (2, 4),
                        (4, 5)]),
    ]
    from repro.core import graph as G
    As, cands, gs = [], [], []
    for _, n, edges in specs:
        rows = [0] * n
        for u, v in edges:
            rows[u] |= 1 << v
            rows[v] |= 1 << u
        As.append(pack_rows(rows, T))
        cands.append(pack_mask((1 << n) - 1, T))
        gs.append(G.from_edges(n, edges))
    return np.stack(As), np.stack(cands), gs


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T", [32, 64])
@pytest.mark.parametrize("l", [1, 2, 3, 4, 5])
def test_count_backends_match_brute_force(T, l):
    rng = np.random.default_rng(T * 100 + l)
    A, cand, gs = packed_tiles(rng, 5, T)
    exp = np.asarray([oracle.count_kcliques_brute(g, l) for g in gs],
                     dtype=np.uint32)
    got_lax = np.asarray(ops.count_tiles(A, cand, l, backend="lax"))
    got_pal = np.asarray(ops.count_tiles(A, cand, l, backend="pallas"))
    got_ref = np.asarray(ops.count_tiles(A, cand, l, backend="ref"))
    np.testing.assert_array_equal(got_lax, exp)
    np.testing.assert_array_equal(got_pal, exp)
    np.testing.assert_array_equal(got_ref, exp)


@pytest.mark.parametrize("T", [32])
@pytest.mark.parametrize("l", [1, 2, 3, 4, 5])
def test_list_backends_byte_identical_capacity_sweep(T, l):
    """(buffer, count, overflow) triples are byte-identical across
    backends for every capacity, including overflowing ones."""
    rng = np.random.default_rng(T * 10 + l)
    A, cand, gs = packed_tiles(rng, 4, T)
    exp = [sorted(oracle.list_kcliques_brute(g, l)) for g in gs]
    for cap in (1, 2, 8, max(max(map(len, exp)), 1)):
        out_lax = [np.asarray(x)
                   for x in ops.list_tiles(A, cand, l, cap, backend="lax")]
        out_pal = [np.asarray(x)
                   for x in ops.list_tiles(A, cand, l, cap,
                                           backend="pallas")]
        for a, b in zip(out_lax, out_pal):
            np.testing.assert_array_equal(a, b)
        bufs, cnt, ovf = out_lax
        for b, want in enumerate(exp):
            assert int(cnt[b]) == len(want)
            assert bool(ovf[b]) == (len(want) > cap)
            got = [tuple(r) for r in bufs[b][: min(len(want), cap)].tolist()]
            assert got == want[: min(len(want), cap)]
            # slots past the emitted prefix stay zeroed on every backend
            assert (bufs[b][min(len(want), cap):] == 0).all()


@pytest.mark.parametrize("l", [3, 4])
def test_lifted_base_case_on_triangle_boundary_tiles(l):
    """The l'==3 close on tiles with zero/one/many triangles, exactly at
    the l==3 (no DFS at all) and l==4 (one DFS level) boundaries."""
    A, cand, gs = crafted_triangle_tiles()
    exp_counts = np.asarray([oracle.count_kcliques_brute(g, l) for g in gs],
                            dtype=np.uint32)
    for backend in ("lax", "pallas"):
        got = np.asarray(ops.count_tiles(A, cand, l, backend=backend,
                                         method="dfs" if backend == "pallas"
                                         else "auto"))
        np.testing.assert_array_equal(got, exp_counts, err_msg=backend)
        bufs, cnt, ovf = (np.asarray(x)
                          for x in ops.list_tiles(A, cand, l, 32,
                                                  backend=backend))
        np.testing.assert_array_equal(cnt, exp_counts, err_msg=backend)
        assert not ovf.any()
        for b, g in enumerate(gs):
            want = sorted(oracle.list_kcliques_brute(g, l))
            got_rows = [tuple(r) for r in bufs[b][: len(want)].tolist()]
            assert got_rows == want, (backend, b)


def test_count_tiles_low_l_closed_forms():
    """l <= 2 is answered by the closed-form ref path on every backend
    (regression: this used to be an unreachable None-returning branch)."""
    rng = np.random.default_rng(5)
    A, cand, gs = packed_tiles(rng, 3, 32)
    for l in (1, 2):
        exp = np.asarray([oracle.count_kcliques_brute(g, l) for g in gs],
                         dtype=np.uint32)
        for backend in ("lax", "pallas", "ref"):
            got = ops.count_tiles(A, cand, l, backend=backend)
            assert got is not None
            np.testing.assert_array_equal(np.asarray(got), exp)


def test_list_tiles_rejects_ref_backend():
    rng = np.random.default_rng(6)
    A, cand, _ = packed_tiles(rng, 2, 32)
    with pytest.raises(ValueError):
        ops.list_tiles(A, cand, 3, 8, backend="ref")


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_resolve_backend_precedence(monkeypatch):
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    # default auto -> lax off-TPU (this suite runs on CPU hosts)
    assert ops.resolve_backend() == "lax"
    # deprecated interpret alias pins pallas
    assert ops.resolve_backend(interpret=True) == "pallas"
    assert ops.resolve_backend(interpret=False) == "pallas"
    # env overrides the alias but not an explicit argument
    monkeypatch.setenv(ops.BACKEND_ENV, "lax")
    assert ops.resolve_backend(interpret=True) == "lax"
    assert ops.resolve_backend("pallas", interpret=True) == "pallas"
    monkeypatch.setenv(ops.BACKEND_ENV, "pallas")
    assert ops.resolve_backend() == "pallas"
    assert ops.resolve_backend("lax") == "lax"
    # explicit auto re-enables auto resolution
    assert ops.resolve_backend("auto") == "lax"
    monkeypatch.setenv(ops.BACKEND_ENV, "bogus")
    with pytest.raises(ValueError):
        ops.resolve_backend()
    with pytest.raises(ValueError):
        ops.resolve_backend("bogus")


def test_autotune_picks_and_caches(monkeypatch):
    from repro import tune

    # layers 2-4 of the resolution ladder: a concrete REPRO_BACKEND (the
    # CI matrix exports lax) legitimately short-circuits before the cache
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    ops.clear_autotune_cache()
    choice = ops.autotune_backend("count", 4, 32)
    assert choice in ("lax", "pallas")
    # the key folds in the device kind (a record measured on one
    # accelerator must not answer for another) and, for listing, the
    # capacity bucket (the emit buffer rides the DFS carry)
    key = (tune.device_kind(), "count", 4, 32, tune.capacity_bucket(None))
    assert ops._AUTOTUNE_CACHE[key] == choice
    # cached: second call returns identically without re-benchmarking
    assert ops.autotune_backend("count", 4, 32) == choice
    # end to end through the registry
    rng = np.random.default_rng(7)
    A, cand, gs = packed_tiles(rng, 3, 32)
    got = np.asarray(ops.count_tiles(A, cand, 4, backend="autotune"))
    exp = np.asarray(ref.clique_count_tiles_ref(A, cand, 4))
    np.testing.assert_array_equal(got, exp)


def test_autotune_key_separates_capacity_buckets(monkeypatch):
    """Regression: the autotune cache key must fold in (device kind,
    capacity bucket) -- a winner measured for a tiny emit buffer must not
    answer for a huge one (the buffer rides the DFS carry), and listing
    must never share entries with counting."""
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    ops.clear_autotune_cache()
    ops.autotune_backend("list", 2, 32, capacity=64)
    ops.autotune_backend("list", 2, 32, capacity=4096)
    ops.autotune_backend("count", 2, 32)
    keys = list(ops._AUTOTUNE_CACHE)
    assert len(keys) == 3, keys
    # same signature, same bucket: served from cache, no 4th entry
    ops.autotune_backend("list", 2, 32, capacity=64)
    assert len(ops._AUTOTUNE_CACHE) == 3
    # capacities rounding to the same pow2 bucket share one entry
    ops.autotune_backend("list", 2, 32, capacity=33)
    ops.autotune_backend("list", 2, 32, capacity=64)  # both bucket to 6
    assert len(ops._AUTOTUNE_CACHE) == 3


def test_lax_backend_lane_padding_is_neutral():
    """Odd batch sizes are padded to a power of two with zero-cand lanes;
    results must be invariant to the padding."""
    rng = np.random.default_rng(8)
    A, cand, gs = packed_tiles(rng, 5, 32)  # 5 -> padded to 8 internally
    exp = np.asarray([oracle.count_kcliques_brute(g, 4) for g in gs],
                     dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(lax_backend.count_tiles(A, cand, 4)), exp)
    sub = np.asarray(lax_backend.count_tiles(A[:3], cand[:3], 4))
    np.testing.assert_array_equal(sub, exp[:3])


def test_lax_listing_chunking_invariant():
    """Chunked and unchunked listing produce identical triples."""
    rng = np.random.default_rng(9)
    A, cand, _ = packed_tiles(rng, 6, 32)
    base = [np.asarray(x) for x in lax_backend.list_tiles(A, cand, 3, 16)]
    import repro.kernels.lax_backend as lb
    old = lb._EMIT_BYTES_BUDGET
    try:
        lb._EMIT_BYTES_BUDGET = 1  # force 1-lane chunks
        chunked = [np.asarray(x) for x in lb.list_tiles(A, cand, 3, 16)]
    finally:
        lb._EMIT_BYTES_BUDGET = old
    for a, b in zip(base, chunked):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# engine / API level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ["truss", "hybrid", "color"])
def test_engine_count_backend_parity(order):
    rng = np.random.default_rng(11)
    g = random_graph(rng, n_lo=12, n_hi=22, p_lo=0.4, p_hi=0.8)
    for k in range(3, 7):
        ref_c = ebbkc.count(g, k, order=order).count
        for backend in ("lax", "pallas"):
            got = ebbkc.count(g, k, order=order, backend="jax",
                              engine_kwargs={"backend": backend}).count
            assert got == ref_c, (order, k, backend)


@pytest.mark.parametrize("order", ["truss", "hybrid", "color"])
def test_engine_listing_backend_byte_parity(order):
    """Decoded clique arrays are byte-identical across backends (and match
    the host oracle as a set), including under tight capacities that force
    the overflow -> host spill path."""
    rng = np.random.default_rng(13)
    g = random_graph(rng, n_lo=12, n_hi=20, p_lo=0.5, p_hi=0.85)
    for k in (3, 4, 5, 6):
        host, _ = ebbkc.list_cliques(g, k, order=order)
        # backends must agree byte-for-byte *within* a capacity mode (an
        # overflowed tile is re-listed by the host recursion, whose
        # deterministic within-tile order legitimately differs from the
        # kernel's lexicographic one -- pre-existing PR3 semantics)
        for cap_kw in ({}, {"capacity": 2}):
            outs = {}
            for backend in ("lax", "pallas"):
                got, st = ebbkc.list_cliques(
                    g, k, order=order, backend="jax",
                    engine_kwargs=dict(backend=backend, **cap_kw))
                outs[backend] = got
                assert sorted(map(tuple, got.tolist())) == \
                    sorted(map(tuple, host.tolist())), (order, k, backend)
            np.testing.assert_array_equal(
                outs["lax"], outs["pallas"],
                err_msg=str((order, k, cap_kw)))


def test_stats_report_backend_and_compile_time():
    rng = np.random.default_rng(17)
    g = random_graph(rng, n_lo=10, n_hi=16, p_lo=0.5, p_hi=0.8)
    r = ebbkc.count(g, 5, backend="jax", engine_kwargs={"backend": "lax"})
    assert r.stats.backend == "lax"
    assert r.stats.kernel_compile_s >= 0.0
    r2 = ebbkc.count(g, 5, backend="jax",
                     engine_kwargs={"backend": "pallas"})
    assert r2.stats.backend == "pallas"
    _, st = ebbkc.list_cliques(g, 5, backend="jax",
                               engine_kwargs={"backend": "lax"})
    assert st.backend == "lax"
    host_r = ebbkc.count(g, 5)
    assert host_r.stats.backend == "host"
