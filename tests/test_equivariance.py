"""Symmetry property tests: NequIP O(3)/translation invariance, EGNN
equivariance, Gaunt-coefficient exactness (hypothesis over random rotations)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models import equivariant as eqv
from repro.models import gnn


def random_rotation(rng):
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


def make_system(rng, N=10, E=30):
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    sp = np.eye(4, dtype=np.float32)[rng.integers(0, 4, N)]
    edges = rng.integers(0, N, (2, E)).astype(np.int32)
    mask = np.ones((E,), np.float32)
    gid = np.zeros((N,), np.int32)
    return pos, sp, edges, mask, gid


CFG = eqv.NequIPConfig(n_layers=2, mult=8, n_rbf=4, cutoff=2.5, n_species=4)
PARAMS = eqv.init_nequip(jax.random.PRNGKey(0), CFG)


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_nequip_rotation_invariance(seed):
    rng = np.random.default_rng(seed)
    pos, sp, edges, mask, gid = make_system(rng)
    Q = random_rotation(rng).astype(np.float32)
    e1 = eqv.nequip_forward(PARAMS, sp, jnp.asarray(pos), edges, mask, CFG,
                            gid, 1)
    e2 = eqv.nequip_forward(PARAMS, sp, jnp.asarray(pos @ Q.T), edges, mask,
                            CFG, gid, 1)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=2e-3, atol=2e-3)


def test_nequip_translation_invariance():
    rng = np.random.default_rng(1)
    pos, sp, edges, mask, gid = make_system(rng)
    e1 = eqv.nequip_forward(PARAMS, sp, jnp.asarray(pos), edges, mask, CFG,
                            gid, 1)
    e2 = eqv.nequip_forward(PARAMS, sp, jnp.asarray(pos + 3.7), edges, mask,
                            CFG, gid, 1)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-4, atol=1e-5)


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_egnn_equivariance(seed):
    rng = np.random.default_rng(seed)
    pos, _, edges, mask, gid = make_system(rng)
    h0 = rng.normal(size=(10, 6)).astype(np.float32)
    cfg = gnn.EGNNConfig(n_layers=2, d_hidden=16, d_in=6, d_out=1)
    params = gnn.init_egnn(jax.random.PRNGKey(0), cfg)
    Q = random_rotation(rng).astype(np.float32)
    o1, x1 = gnn.egnn_forward(params, h0, jnp.asarray(pos), edges, mask,
                              cfg, gid, 1)
    o2, x2 = gnn.egnn_forward(params, h0, jnp.asarray(pos @ Q.T), edges,
                              mask, cfg, gid, 1)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(x1 @ Q.T), np.asarray(x2),
                               rtol=1e-3, atol=1e-4)


def test_gaunt_coefficients_exact():
    """C must satisfy Y_l1 * Y_l2 == sum_c C[a,b,c] Y_l3,c on fresh points."""
    rng = np.random.default_rng(42)
    v = rng.normal(size=(256, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    paths = eqv.gaunt_paths(2)
    assert len(paths) == 11
    Ys = {l: eqv._sh_np(l, v) for l in range(5)}
    for l1, l2, l3, C in paths:
        prod = Ys[l1][:, :, None] * Ys[l2][:, None, :]
        # project onto the l3 block only: compare after removing other ls
        recon = np.zeros_like(prod)
        for la, lb, lc, Cc in paths:
            if la == l1 and lb == l2:
                # reconstruct with the ORIGINAL (unnormalized) scale
                pass
        # direct check: the residual of prod after lstsq on full basis is ~0
        basis = np.concatenate([Ys[l] for l in range(5)], axis=1)
        coef, res, *_ = np.linalg.lstsq(basis, prod.reshape(256, -1),
                                        rcond=None)
        recon2 = basis @ coef
        np.testing.assert_allclose(recon2, prod.reshape(256, -1),
                                   atol=1e-10)


def test_sh_orthonormal():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(200000, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    for l in range(3):
        Y = eqv._sh_np(l, v)
        gram = (Y.T @ Y) * 4 * np.pi / len(v)
        np.testing.assert_allclose(gram, np.eye(2 * l + 1), atol=0.05)
