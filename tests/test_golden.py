"""Golden-fixture tests: committed small graphs with known k-clique counts.

``tests/fixtures/golden_graphs.json`` pins real/canonical graphs --
Zachary's karate club (whose 45 triangles / 11 4-cliques / 2 5-cliques
match the published values), the K2,2,2 octahedron, and the triangle-free
Petersen graph -- together with brute-force-verified counts for k in
3..7.  Every engine, ordering, backend, and device count must reproduce
them *exactly*, so CI catches silent count drift without needing the
bench-smoke job.  Regenerate the fixture only from a trusted revision
(the generator recipe is in CHANGES.md / the PR that added it).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import ebbkc, engine_jax, listing
from repro.core.graph import from_edges

N_DEV = jax.device_count()
_FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                        "golden_graphs.json")


def _load():
    with open(_FIXTURE) as f:
        raw = json.load(f)
    out = {}
    for name, spec in raw.items():
        g = from_edges(spec["n"], np.asarray(spec["edges"], np.int64))
        out[name] = (g, {int(k): v for k, v in spec["counts"].items()})
    return out


GOLDEN = _load()


def test_fixture_integrity():
    """The committed karate fixture is the real Zachary graph."""
    g, counts = GOLDEN["karate"]
    assert (g.n, g.m) == (34, 78)
    assert counts[3] == 45 and counts[4] == 11 and counts[5] == 2
    gp, cp = GOLDEN["petersen"]
    assert gp.m == 15 and all(v == 0 for v in cp.values())


@pytest.mark.parametrize("name", sorted(GOLDEN))
@pytest.mark.parametrize("order", ["truss", "hybrid", "color"])
def test_host_engine_matches_golden(name, order):
    g, counts = GOLDEN[name]
    for k, want in counts.items():
        r = ebbkc.count(g, k, order=order)
        assert r.count == want, (name, order, k)
        # listing agrees with counting (exact-once)
        rows, _ = ebbkc.list_cliques(g, k, order=order)
        assert rows.shape == (want, k), (name, order, k)


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_jax_engine_matches_golden(name):
    """Session backend (REPRO_BACKEND in CI), 1 and all local devices."""
    g, counts = GOLDEN[name]
    for k, want in counts.items():
        for devices in (None, 1, N_DEV):
            r = engine_jax.count(g, k, devices=devices)
            assert r.count == want, (name, k, devices)


@pytest.mark.parametrize("backend", ["lax", "pallas", "ref"])
def test_every_backend_matches_golden(backend):
    """Explicit backend sweep on the small fixtures (karate is covered by
    the session-backend test above; pallas-interpret on all its k would
    dominate suite time)."""
    for name in ("octahedron", "petersen"):
        g, counts = GOLDEN[name]
        for k in (3, 4, 5):
            r = engine_jax.count(g, k, backend=backend)
            assert r.count == counts[k], (name, backend, k)


def test_listing_subsystem_matches_golden():
    g, counts = GOLDEN["karate"]
    for k in (3, 4, 5):
        sink = listing.ArraySink(k)
        listing.stream_cliques(g, k, sink, devices=N_DEV)
        assert sink.accepted == counts[k], k
        rows = sink.result()
        # exact-once, sorted rows, valid vertex ids
        assert rows.shape == (counts[k], k)
        if rows.shape[0]:
            assert (np.diff(rows, axis=1) > 0).all()
            assert rows.min() >= 0 and rows.max() < g.n
            uniq = np.unique(rows, axis=0)
            assert uniq.shape[0] == rows.shape[0]
