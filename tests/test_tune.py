"""Persistent autotuner (repro.tune): records, cache, search, wiring.

Covers the PR-6 contracts: corrupt / stale-format records read as absent
(never crash), the ``REPRO_BACKEND`` env beats a persisted record, a
persisted record beats a live microbenchmark (warm processes never
re-measure / re-search), concurrent same-directory writers stay atomic,
geometry resolution follows the arg > record > default ladder, and the
batch-shape bucketing that lets padded lanes reuse executables is
result-neutral.
"""
import dataclasses
import json
import os
import threading

import numpy as np
import pytest

from repro import tune
from repro.core.bitops import pack_bits
from repro.kernels import ops
from repro.tune import cache as tcache
from repro.tune import search as tsearch
from repro.tune.records import TuningRecord


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    """Isolated tuning cache: fresh dir, no env leakage, clean globals."""
    monkeypatch.delenv(tcache.ENV_TUNE_CACHE, raising=False)
    monkeypatch.delenv(ops.BACKEND_ENV, raising=False)
    d = str(tmp_path / "tc")
    tune.configure(d, xla_cache=False)  # never mutate global jax config
    tune.clear_memory()
    tune.consume_events()
    ops.clear_autotune_cache()
    yield d
    tune.configure(None)
    tune.clear_memory()
    tune.consume_events()
    ops.clear_autotune_cache()


def _backend_rec(winner="pallas", mode="count", l=2, T=32, capacity=None):
    return TuningRecord(
        "backend", tune.device_kind(), tune.jax_version(), mode, l,
        T=T, W=T // 32, cap_bucket=tune.capacity_bucket(capacity),
        data={"winner": winner})


def _record_meta_path(tune_dir):
    [digest] = os.listdir(os.path.join(tune_dir, "records"))
    return os.path.join(tune_dir, "records", digest,
                        "step_0000000000", "meta.json")


def test_record_roundtrip_across_processes(tune_dir):
    rec = _backend_rec("lax")
    tune.put(rec)
    tune.clear_memory()  # "new process": only the directory survives
    got = tune.get(rec.key())
    assert got is not None
    assert got.data["winner"] == "lax"
    assert got.key() == rec.key()


def test_corrupt_record_reads_as_absent(tune_dir):
    rec = _backend_rec("lax")
    tune.put(rec)
    with open(_record_meta_path(tune_dir), "w") as f:
        f.write("{ not json")
    tune.clear_memory()
    assert tune.get(rec.key()) is None
    # ...and backend="autotune" falls back to a live measurement, no crash
    assert ops.autotune_backend("count", 2, 32) in ("lax", "pallas")


def test_stale_format_record_reads_as_absent(tune_dir):
    rec = _backend_rec("pallas")
    tune.put(rec)
    p = _record_meta_path(tune_dir)
    with open(p) as f:
        meta = json.load(f)
    meta["metadata"]["format"] = 0  # a pre-PR6 layout
    with open(p, "w") as f:
        json.dump(meta, f)
    tune.clear_memory()
    assert tune.get(rec.key()) is None


def test_env_backend_overrides_persisted_record(tune_dir, monkeypatch):
    tune.put(_backend_rec("pallas"))
    monkeypatch.setenv(ops.BACKEND_ENV, "lax")
    assert ops.autotune_backend("count", 2, 32) == "lax"
    # the env short-circuit consults no cache layer: no events at all
    assert tune.consume_events() == (0.0, 0, 0)


def test_persisted_record_skips_microbench(tune_dir, monkeypatch):
    tune.put(_backend_rec("lax"))
    monkeypatch.setattr(
        tsearch, "microbench_backend",
        lambda *a, **k: pytest.fail("microbenchmark re-ran on a warm key"))
    assert ops.autotune_backend("count", 2, 32) == "lax"
    tune_s, lookups, misses = tune.consume_events()
    assert lookups == 1 and misses == 0
    # second call answers from the in-process layer, same verdict
    assert ops.autotune_backend("count", 2, 32) == "lax"
    _, lookups, misses = tune.consume_events()
    assert lookups == 1 and misses == 0


def test_concurrent_writers_stay_atomic(tune_dir):
    """Same-key writers race benignly: no exceptions escape, and a reader
    only ever sees a fully committed record (or none), never garbage."""
    rec = _backend_rec("lax")
    errors = []

    def write(winner):
        try:
            for _ in range(5):
                tune.put(_backend_rec(winner))
        except Exception as e:  # pragma: no cover - the bug being tested
            errors.append(e)

    threads = [threading.Thread(target=write, args=(w,))
               for w in ("lax", "pallas") * 3]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    tune.clear_memory()
    got = tune.get(rec.key())
    assert got is None or got.data["winner"] in ("lax", "pallas")
    # the cache recovers: one clean write-after-the-race round-trips
    tune.put(_backend_rec("lax"))
    tune.clear_memory()
    assert tune.get(rec.key()).data["winner"] == "lax"


def test_geometry_precedence_ladder(tune_dir):
    # no record anywhere: the hardcoded defaults
    g0 = tsearch.resolve_geometry("list", 3)
    assert (g0.batch_size, g0.t_policy, g0.cap_policy) == \
        (256, "pow2", "pow2")
    assert tune.consume_events() == (0.0, 0, 0)  # untuned != cache miss
    # a persisted record becomes the default...
    tune.put(TuningRecord(
        "geometry", tune.device_kind(), tune.jax_version(), "list", 3,
        data={"batch_size": 64, "t_policy": "mult32",
              "cap_policy": "mult64"}))
    tune.clear_memory()
    g1 = tsearch.resolve_geometry("list", 3)
    assert g1.batch_size == 64
    assert g1.bins == tsearch.bins_for("mult32")
    assert g1.cap_policy == "mult64"
    _, lookups, misses = tune.consume_events()
    assert lookups == 1 and misses == 0
    # ...but an explicit argument still wins, per knob
    g2 = tsearch.resolve_geometry("list", 3, batch_size=128,
                                  cap_policy="pow2")
    assert g2.batch_size == 128
    assert g2.cap_policy == "pow2"
    assert g2.bins == tsearch.bins_for("mult32")  # inherited from record
    # an explicit ladder wins even when it matches no named policy
    # (bins=(32,) is how the spill tests force oversize tiles)
    g3 = tsearch.resolve_geometry("list", 3, bins=(32,))
    assert g3.bins == (32,)
    g4 = tsearch.resolve_geometry("list", 3, bins=(32, 64, 128, 256))
    assert g4.bins == tsearch.bins_for("pow2")
    assert g4.t_policy == "pow2"  # a policy-shaped ladder maps back


def test_warm_process_reuses_tuned_geometry_without_search(tune_dir,
                                                          monkeypatch):
    from repro.data import rmat_graph

    rec = tsearch.tune_geometry("count", 1, budget_s=2.0,
                                graph=rmat_graph(7, 6, seed=1))
    tuned = tsearch.geometry_from_record(rec)
    # "second process": in-memory layers gone, only the record dir remains
    tune.clear_memory()
    ops.clear_autotune_cache()
    monkeypatch.setattr(
        tsearch, "_eval_geometry",
        lambda *a, **k: pytest.fail("geometry re-searched on a warm key"))
    got = tsearch.resolve_geometry("count", 1)
    assert dataclasses.asdict(got) == dataclasses.asdict(tuned)
    assert rec.data["searched"] and rec.data["evals"] >= 1


def _packed(rng, B, T):
    dense = rng.random((B, T, T)) < 0.4
    dense = np.triu(dense, 1)
    dense = dense | dense.transpose(0, 2, 1)
    return pack_bits(dense), pack_bits(np.ones((B, T), bool))


def test_bucket_rows_padding_is_neutral():
    """Batch-shape bucketing pads to the next pow2 with zero-cand lanes so
    padded batches reuse executables; the pads must contribute nothing."""
    from repro.core.engine_jax import bucket_rows

    rng = np.random.default_rng(5)
    A, cand = _packed(rng, 5, 32)
    Ab, cb = bucket_rows(A), bucket_rows(cand)
    assert Ab.shape[0] == 8 and cb.shape[0] == 8
    assert (Ab[5:] == 0).all() and (cb[5:] == 0).all()
    assert bucket_rows(Ab) is Ab  # already a pow2: no copy
    base = np.asarray(ops.count_tiles(A, cand, 2, backend="lax"))
    padded = np.asarray(ops.count_tiles(Ab, cb, 2, backend="lax"))
    np.testing.assert_array_equal(padded[:5], base)
    assert (padded[5:] == 0).all()
    buf, cnts, ovf = (np.asarray(x) for x in
                      ops.list_tiles(Ab, cb, 2, capacity=64, backend="lax"))
    np.testing.assert_array_equal(cnts[:5], base)
    assert (cnts[5:] == 0).all() and not ovf[5:].any()


def test_drain_tune_events_never_clobbers_verdict(tune_dir):
    from repro.core.engine_np import Stats

    st = Stats()
    tune.note_event(seconds=0.5, lookup=True, miss=True)
    ops.drain_tune_events(st)
    assert st.tune_s == 0.5 and st.tune_cache_hit is False
    tune.note_event(lookup=True)
    ops.drain_tune_events(st)
    assert st.tune_cache_hit is True
    # an empty drain (engines and dispatchers share one Stats and both
    # drain) must leave the verdict and the seconds untouched
    ops.drain_tune_events(st)
    assert st.tune_cache_hit is True and st.tune_s == 0.5
