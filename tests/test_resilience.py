"""Fault-injection, retry/fallback, and graceful degradation (ISSUE 9).

The chaos-determinism gate: with a seeded fault plan active at every
named site (including a double-digit share of kernel launches), counts
stay exact and listing output stays byte-identical to the fault-free
run, with nonzero retry/demotion accounting and no hangs.  Plus the
isolation gates (one bad request never takes down its cotenants; a
deadline-enforced request cancels cooperatively), artifact quarantine,
and the disabled-injection overhead budget.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to chaos
the multi-device dispatch paths too (the CI matrix does both 1 and 4).
"""

import logging
import time

import numpy as np
import pytest

from conftest import random_graph
from repro.checkpoint import store
from repro.core import engine_jax, listing, pipeline
from repro.core.engine_np import Stats
from repro.data import rmat_graph
from repro.resilience import inject, retry
from repro.runtime import dispatch as dsp
from repro.serve import CliqueService, DeadlineExceeded, ServiceClosed

#: every site armed; kernel.launch well above the >=10% gate requirement
CHAOS_PLAN = ("seed=11;plan.load=0.3;extract=0.3;pack=0.3;device.stage=0.3;"
              "kernel.launch=0.3;device.harvest=0.3;decode=0.3;"
              "sink.write=0.3;tune.read=0.3")


@pytest.fixture(autouse=True)
def _clean_injection():
    inject.configure(None)
    yield
    inject.configure(None)


def make_graph(seed=3, n=48, edges=700):
    rng = np.random.default_rng(seed)
    es = set()
    while len(es) < edges:
        a, b = (int(x) for x in rng.integers(0, n, 2))
        if a != b:
            es.add((min(a, b), max(a, b)))
    from repro.core import graph as G
    return G.from_edges(n, sorted(es))


# ---------------------------------------------------------------------------
# fault-plan parsing + deterministic schedule
# ---------------------------------------------------------------------------


def test_fault_plan_parse():
    plan = inject.FaultPlan.parse("seed=9;*=0.1;kernel.launch=0.5:delay:0.01")
    assert plan.seed == 9
    assert plan.rules["decode"].rate == 0.1
    assert plan.rules["decode"].kind == "raise"
    assert plan.rules["kernel.launch"].rate == 0.5
    assert plan.rules["kernel.launch"].kind == "delay"
    assert plan.rules["kernel.launch"].param == 0.01
    with pytest.raises(ValueError):
        inject.FaultPlan.parse("nonsense.site=0.5")
    with pytest.raises(ValueError):
        inject.FaultPlan.parse("decode=0.5:explode")


def test_fault_schedule_is_deterministic():
    inject.configure("seed=4;decode=0.5")
    first = []
    for _ in range(64):
        try:
            inject.fire("decode")
            first.append(False)
        except inject.FaultInjected:
            first.append(True)
    assert any(first) and not all(first)
    # same plan, reset counters -> identical schedule, call for call
    inject.reset_counts()
    for i in range(64):
        fired = False
        try:
            inject.fire("decode")
        except inject.FaultInjected:
            fired = True
        assert fired == first[i], i
    # a different seed produces a different schedule
    inject.configure("seed=5;decode=0.5")
    second = []
    for _ in range(64):
        try:
            inject.fire("decode")
            second.append(False)
        except inject.FaultInjected:
            second.append(True)
    assert second != first


def test_disabled_injection_is_noop_and_cheap():
    # off by default: fire() at any site is a no-op...
    inject.configure(None)
    for site in inject.SITES:
        inject.fire(site)
    # ...and cheap enough that baked-in sites cost <= 1% of engine work
    # (same budget methodology as the disabled-tracer test in test_obs)
    g = rmat_graph(6, 6, seed=3)

    def workload():
        t0 = time.perf_counter()
        engine_jax.count(g, 4, batch_size=64)
        return time.perf_counter() - t0

    workload()  # warm executables + plan caches
    work_s = min(workload() for _ in range(3))

    # count how many site calls that workload makes (epsilon-rate plan:
    # every call advances the schedule, none of them fire at 1e-12)
    inject.configure("seed=1;*=0.000000000001")
    engine_jax.count(g, 4, batch_size=64)
    n_calls = sum(inject.calls().values())
    assert sum(inject.fired().values()) == 0
    inject.configure(None)
    assert n_calls > 0

    n_iter = 50_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        inject.fire("kernel.launch")
    per_call = (time.perf_counter() - t0) / n_iter
    overhead = per_call * n_calls
    assert overhead <= 0.01 * work_s, (
        f"disabled injection costs {overhead * 1e3:.3f}ms over {n_calls} "
        f"site calls vs {work_s * 1e3:.1f}ms of work")


# ---------------------------------------------------------------------------
# retry / backoff / demotion units
# ---------------------------------------------------------------------------


def test_backoff_delay_capped_and_deterministic():
    pol = retry.RetryPolicy(max_attempts=8, base_delay_s=0.001,
                            max_delay_s=0.004, jitter=0.5, seed=2)
    delays = [retry.backoff_delay(pol, a, token="t") for a in range(1, 8)]
    assert all(0 < d <= 0.004 for d in delays)
    assert delays == [retry.backoff_delay(pol, a, token="t")
                      for a in range(1, 8)]
    # exponential growth up to the cap (jitter only ever shrinks)
    assert retry.backoff_delay(
        retry.RetryPolicy(jitter=0.0), 2) == 2 * retry.backoff_delay(
        retry.RetryPolicy(jitter=0.0), 1)


def test_retry_call_retries_then_raises():
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"

    pol = retry.RetryPolicy(max_attempts=3, base_delay_s=0.0)
    assert retry.call(flaky, policy=pol, retry_on=(RuntimeError,)) == "ok"
    assert len(attempts) == 3

    with pytest.raises(RuntimeError):
        retry.call(lambda: (_ for _ in ()).throw(RuntimeError("always")),
                   policy=pol, retry_on=(RuntimeError,))


def test_demotion_ladders():
    assert retry.demote("count", "pallas") == "lax"
    assert retry.demote("count", "lax") == "ref"
    assert retry.demote("count", "ref") is None
    assert retry.demote("list", "pallas") == "lax"
    assert retry.demote("list", "lax") is None
    # an off-ladder backend (None = unresolved, host, ...) has no rung
    # below it: the caller falls straight back to the host recursion
    assert retry.demote("count", None) is None
    assert retry.demote("count", "host") is None


# ---------------------------------------------------------------------------
# artifact quarantine (checkpoints + plan cache)
# ---------------------------------------------------------------------------


@pytest.fixture
def _propagate_repro_logs():
    # obs.logging.setup_logging (called by other tests) turns off
    # propagation on the "repro" logger; caplog listens on root.
    root = logging.getLogger("repro")
    prev = root.propagate
    root.propagate = True
    yield
    root.propagate = prev


def _corrupt_arrays(directory, mode="truncate"):
    import os
    step = store.latest_step(directory)
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        if mode == "truncate":
            f.write(blob[: len(blob) // 2])
        else:
            f.write(b"garbage" * 64)


@pytest.mark.parametrize("mode", ["truncate", "garbage"])
def test_corrupt_checkpoint_detected_and_quarantined(
    tmp_path, caplog, mode, _propagate_repro_logs
):
    d = str(tmp_path / "ck")
    store.save_checkpoint(d, 0, {"a": np.arange(100)})
    assert store.restore_checkpoint(d)["tree"]["a"].shape == (100,)
    _corrupt_arrays(d, mode)
    with pytest.raises(store.CorruptCheckpointError):
        store.restore_checkpoint(d)
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        assert store.restore_checkpoint_safe(d) is None
    assert any("quarantined" in r.message for r in caplog.records)
    # the bad step moved aside (inspectable), the slot reads as absent
    assert store.latest_step(d) is None
    assert (tmp_path / "ck" / "quarantine").is_dir()
    # a fresh save rebuilds cleanly in the vacated slot
    store.save_checkpoint(d, 0, {"a": np.arange(7)})
    assert store.restore_checkpoint(d)["tree"]["a"].shape == (7,)


def test_corrupt_plan_cache_rebuilt_with_same_counts(
    tmp_path, caplog, _propagate_repro_logs
):
    g = make_graph(seed=8, n=40, edges=500)
    cache = str(tmp_path / "plans")
    cold = engine_jax.count(g, 5, plan_cache_dir=cache)
    # corrupt every cached plan entry on disk
    import os
    entries = [os.path.join(cache, e) for e in os.listdir(cache)
               if os.path.isdir(os.path.join(cache, e))]
    assert entries
    for e in entries:
        _corrupt_arrays(e)
    # a fresh process would read the corrupt entry from disk; simulate by
    # dropping the in-memory plan layer
    pipeline.clear_plan_cache()
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        rebuilt = engine_jax.count(g, 5, plan_cache_dir=cache)
    assert rebuilt.count == cold.count
    assert any("quarantined" in r.message for r in caplog.records)
    # and the rebuild left a valid cache behind: third run is a warm hit
    stats = Stats()
    pipeline.cached_plan(g, "hybrid", cache_dir=cache, stats=stats)
    assert stats.plan_cache_hit


def test_injected_corruption_on_tune_read_reads_as_absent(tmp_path):
    from repro.tune import cache as tcache
    from repro.tune.records import TuningRecord

    tcache.configure(str(tmp_path / "tune"), xla_cache=False)
    try:
        rec = TuningRecord(kind="backend", device_kind="cpu",
                           jax_version="x", mode="count", l=2, T=32, W=1,
                           cap_bucket=-1, data={"backend": "lax"})
        tcache.put(rec)
        tcache.clear_memory()
        assert tcache.get(rec.key()) is not None  # round-trips from disk
        # a raise on the tune.read site degrades to a miss, never an error
        tcache.clear_memory()
        inject.configure("seed=1;tune.read=1.0")
        assert tcache.get(rec.key()) is None
        # a corrupt-kind rule flips blob bytes between read and verify:
        # the integrity trailer catches it and the record reads as absent
        inject.configure("seed=1;tune.read=1.0:corrupt")
        assert tcache.get(rec.key()) is None
        inject.configure(None)
        assert tcache.get(rec.key()) is not None  # record itself unharmed
    finally:
        tcache.configure(None)
        tcache.clear_memory()


# ---------------------------------------------------------------------------
# chaos determinism: engines under a seeded plan at every site
# ---------------------------------------------------------------------------


def test_chaos_count_exact_with_retries():
    # devices=1 routes through the Dispatcher (retry + demotion ladder);
    # the serve tier always takes this path
    g = make_graph()
    want = engine_jax.count(g, 5, devices=1).count
    inject.configure(CHAOS_PLAN)
    res = engine_jax.count(g, 5, devices=1)
    n_fired = sum(inject.fired().values())
    inject.configure(None)
    assert res.count == want
    assert res.stats.retries > 0
    assert n_fired > 0


@pytest.mark.parametrize("capacity", ["sized", "speculative"])
def test_chaos_listing_byte_identical(capacity):
    g = make_graph(seed=5)
    kwargs = {"capacity": capacity, "devices": 1}
    sink = listing.ArraySink(5)
    listing.stream_cliques(g, 5, sink, **kwargs)
    want = sink.result()
    inject.configure(CHAOS_PLAN)
    sink = listing.ArraySink(5)
    res = listing.stream_cliques(g, 5, sink, **kwargs)
    got = sink.result()
    inject.configure(None)
    assert np.array_equal(got, want)
    assert res.stats.retries > 0


def test_kernel_launch_certain_failure_demotes_to_exact_host():
    g = make_graph(seed=9, n=36, edges=420)
    want = engine_jax.count(g, 4).count
    sink = listing.ArraySink(4)
    listing.stream_cliques(g, 4, sink)
    want_rows = sink.result()
    # rate 1.0: every kernel launch fails, every attempt, forever -- the
    # ladder must walk pallas -> lax -> ref -> host and still be exact
    inject.configure("seed=5;kernel.launch=1.0")
    res = engine_jax.count(g, 4, devices=1)
    sink = listing.ArraySink(4)
    lres = listing.stream_cliques(g, 4, sink, devices=1)
    rows = sink.result()
    inject.configure(None)
    assert res.count == want
    assert res.stats.demotions > 0
    assert np.array_equal(rows, want_rows)
    assert lres.stats.demotions > 0


def test_chaos_serve_mixed_workload_byte_identical():
    """The PR's chaos gate: a mixed count+list serve workload under the
    all-sites plan returns byte-identical results to the fault-free run,
    with nonzero retry/demotion accounting and no hangs."""
    g1 = make_graph(seed=3)
    g2 = rmat_graph(6, 6, seed=2)
    work = [("g1", 4, "count"), ("g1", 5, "list"), ("g2", 5, "count"),
            ("g2", 4, "list"), ("g1", 5, "count"), ("g1", 4, "list")]

    def run():
        svc = CliqueService(chunk_tiles=16, fuse_rows=64)
        svc.register_graph("g1", g1)
        svc.register_graph("g2", g2)
        svc.pause()
        tickets = [(m, svc.submit(gn, k, m)) for gn, k, m in work]
        svc.resume()
        out = []
        for m, t in tickets:
            r = t.result(timeout=300)
            out.append(r.count if m == "count" else r.rows)
        stats = svc.engine_stats
        svc.close()
        return out, stats

    base, _ = run()
    inject.configure(CHAOS_PLAN)
    got, stats = run()
    n_fired = sum(inject.fired().values())
    inject.configure(None)
    for b, g in zip(base, got):
        if isinstance(b, np.ndarray):
            assert np.array_equal(g, b)
        else:
            assert g == b
    assert stats.retries > 0
    assert n_fired > 0


# ---------------------------------------------------------------------------
# request isolation + deadline enforcement + shutdown semantics
# ---------------------------------------------------------------------------


class _BoomSink(listing.CliqueSink):
    accepted = 0
    bytes_written = 0

    def emit(self, rows):
        raise RuntimeError("sink boom")

    def close(self):
        pass

    def result(self):
        return None


def test_one_request_failure_is_isolated():
    g = make_graph(seed=3)
    want = engine_jax.count(g, 5).count
    svc = CliqueService()
    svc.register_graph("g", g)
    t_bad = svc.submit("g", 5, "list", sink=_BoomSink())
    t_ok = svc.submit("g", 5, "count")
    with pytest.raises(RuntimeError, match="sink boom"):
        t_bad.result(timeout=120)
    assert t_ok.result(timeout=120).count == want  # cotenant unaffected
    # the service is still alive and serving new requests
    assert svc.submit("g", 4, "count").result(timeout=120).count == \
        engine_jax.count(g, 4).count
    assert svc.stats.isolated_failures == 1
    svc.close()


def test_admission_failure_is_isolated():
    g = make_graph(seed=3)
    svc = CliqueService()
    svc.register_graph("g", g)
    svc.register_graph("bad", object())  # cached_plan will reject this
    t_bad = svc.submit("bad", 4, "count")
    with pytest.raises(Exception):
        t_bad.result(timeout=120)
    assert svc.submit("g", 4, "count").result(timeout=120).count == \
        engine_jax.count(g, 4).count
    svc.close()


def test_enforced_deadline_cancels_cooperatively():
    g = make_graph(seed=3)
    want = engine_jax.count(g, 5).count
    svc = CliqueService()
    svc.register_graph("g", g)
    t = svc.submit("g", 6, "list", deadline_s=1e-4, enforce_deadline=True)
    with pytest.raises(DeadlineExceeded) as ei:
        t.result(timeout=120)
    # partials ride on the typed error (possibly empty, never None rows)
    assert ei.value.partial_rows is not None
    assert ei.value.partial_rows.shape[1] == 6
    assert ei.value.emitted == ei.value.partial_rows.shape[0]
    # the service keeps serving, and the cancel was counted
    assert svc.submit("g", 5, "count").result(timeout=120).count == want
    assert svc.stats.deadline_cancels == 1
    svc.close()


def test_unenforced_deadline_still_completes_exactly():
    g = make_graph(seed=3)
    svc = CliqueService()
    svc.register_graph("g", g)
    r = svc.submit("g", 5, "count", deadline_s=1e-5).result(timeout=120)
    assert r.count == engine_jax.count(g, 5).count
    assert r.deadline_missed
    svc.close()


def test_load_shedding_on_projected_miss():
    from repro.serve.request import Request, ServiceOverloaded
    from repro.serve.scheduler import BatchScheduler

    g = make_graph(seed=3)
    sched = BatchScheduler(shed_on_projected_miss=True, fuse_rows=4)
    # forge an observed throughput of ~5 tiles/s inside the rate window
    now = time.monotonic()
    sched._rate_samples.extend([(now - 20.0, 50), (now - 10.0, 50)])
    req = Request(g, 5, "count", deadline_s=0.05)
    req.mark_submitted()
    with pytest.raises(ServiceOverloaded):
        sched.admit(req)
    assert sched.stats.shed == 1
    # without a deadline the same request admits fine
    req2 = Request(g, 5, "count")
    req2.mark_submitted()
    sched.admit(req2)
    sched.fail_active(RuntimeError("test teardown"))
    sched.finish()


def test_shed_cold_start_and_stale_window_are_permissive():
    """Satellite regression: the shed estimator must never reject on a
    missing or stale rate.  Cold (no pulls yet) and post-idle (all
    samples aged out of the window) states admit deadline-bearing
    requests instead of shedding them on a decayed throughput guess."""
    from repro.serve.request import Request
    from repro.serve.scheduler import BatchScheduler

    g = make_graph(seed=3)
    # cold start: no observations at all -> permissive, no ZeroDivision
    sched = BatchScheduler(shed_on_projected_miss=True, fuse_rows=4)
    assert sched._observed_rate() is None
    req = Request(g, 5, "count", deadline_s=1e-6)
    req.mark_submitted()
    sched.admit(req)  # must not raise
    assert sched.stats.shed == 0
    sched.fail_active(RuntimeError("test teardown"))

    # post-idle: old samples fell out of the window -> permissive again.
    # Under the pre-fix lifetime tiles/(now - first_pull) estimator this
    # state read as a near-zero rate and shed the whole next burst.
    now = time.monotonic()
    sched._rate_samples.extend(
        [(now - 3600.0, 1000), (now - 3599.0, 1000)])
    assert sched._observed_rate(now) is None
    req2 = Request(g, 5, "count", deadline_s=1e-6)
    req2.mark_submitted()
    sched.admit(req2)
    assert sched.stats.shed == 0
    # too few recent tiles is also untrustworthy (below fuse_rows)
    sched._rate_samples.append((now - 1.0, 2))
    assert sched._observed_rate(now) is None
    sched.fail_active(RuntimeError("test teardown"))
    sched.finish()


def test_close_drain_false_resolves_active_and_queued():
    g = make_graph(seed=3)
    svc = CliqueService()
    svc.register_graph("g", g)
    svc.pause()  # everything below stays queued until close
    tickets = [svc.submit("g", 5, "count") for _ in range(6)]
    svc.close(drain=False)
    for t in tickets:
        with pytest.raises(ServiceClosed):
            t.result(timeout=30)  # resolves, never hangs
    with pytest.raises(ServiceClosed):
        svc.submit("g", 4, "count")
    svc.close()  # second close is idempotent
    svc.close(drain=False)


def test_close_drain_true_completes_inflight():
    g = make_graph(seed=3)
    want = engine_jax.count(g, 5).count
    svc = CliqueService()
    svc.register_graph("g", g)
    tickets = [svc.submit("g", 5, "count") for _ in range(3)]
    svc.close()
    for t in tickets:
        assert t.result(timeout=30).count == want


def test_list_dispatcher_close_mid_burst_no_torn_rows():
    """Regression for the teardown race: ``close()`` with decode jobs in
    flight must drain them to a barrier, never strand a sink write
    mid-row.  Every emitted row must be a complete, valid clique."""
    g = rmat_graph(8, 4, seed=7)
    k = 4
    batches = [b for b in pipeline.stream_batches(g, k, batch_size=16)
               if isinstance(b, pipeline.TileBatch)]
    assert len(batches) >= 4
    sink = listing.ArraySink(k)
    disp = dsp.ListDispatcher(k - 2, sink=sink, stats=Stats())
    for b in batches:
        disp.submit(b)
    disp.close()  # immediately, with decode work still in flight
    rows = sink.result()
    # all-or-nothing per decode job: each row is fully written (k distinct
    # vertices, no zero-padding torn off a partial write)
    if rows.shape[0]:
        assert rows.shape[1] == k
        assert all(len(set(r.tolist())) == k for r in rows)


def test_chaos_no_spurious_failures_under_serve_smoke_rate():
    """The CI chaos leg's contract in miniature: the loadgen-style rate
    (0.15 everywhere) must produce zero isolated failures -- consume-site
    retries and launch demotions absorb everything."""
    g = make_graph(seed=13)
    want = engine_jax.count(g, 5).count
    inject.configure("seed=7;*=0.15;kernel.launch=0.15")
    svc = CliqueService()
    svc.register_graph("g", g)
    tickets = [svc.submit("g", 5, "count") for _ in range(4)]
    for t in tickets:
        assert t.result(timeout=300).count == want
    assert svc.stats.isolated_failures == 0
    svc.close()
    inject.configure(None)
