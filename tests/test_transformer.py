"""Transformer internals: chunked attention, local windows, prefill/decode
consistency, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tr


def tiny_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_head=8, d_ff=64, vocab=64, q_block=8)
    base.update(kw)
    return tr.TransformerConfig(**base)


def test_chunked_equals_full_attention():
    cfg_c = tiny_cfg(q_block=8)
    cfg_f = tiny_cfg(q_block=64)
    p = tr.init_params(jax.random.PRNGKey(0), cfg_c)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 64)
    np.testing.assert_allclose(np.asarray(tr.forward(p, t, cfg_c)),
                               np.asarray(tr.forward(p, t, cfg_f)),
                               rtol=2e-5, atol=2e-5)


def test_analysis_unroll_same_numerics():
    # unrolling reassociates bf16 reductions; only bf16-level agreement
    cfg = tiny_cfg()
    cfg_u = tiny_cfg(analysis_unroll=True)
    p = tr.init_params(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    np.testing.assert_allclose(np.asarray(tr.forward(p, t, cfg)),
                               np.asarray(tr.forward(p, t, cfg_u)),
                               rtol=5e-2, atol=5e-2)


def test_sliding_window_masks_far_tokens():
    """A local layer's output at position i must not depend on tokens
    further back than the window."""
    cfg = tiny_cfg(local_window=4, local_per_global=100, n_layers=1,
                   q_block=8)
    p = tr.init_params(jax.random.PRNGKey(2), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0, 64)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % 64)  # mutate a far-away token
    o1 = tr.forward(p, t1, cfg)
    o2 = tr.forward(p, t2, cfg)
    # last position is > window away from position 0
    np.testing.assert_allclose(np.asarray(o1[0, -1]), np.asarray(o2[0, -1]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(o1[0, 0]), np.asarray(o2[0, 0]))


@pytest.mark.parametrize("local", [False, True])
def test_prefill_decode_match_forward(local):
    kw = dict(local_window=8, local_per_global=1) if local else {}
    cfg = tiny_cfg(n_layers=4, q_block=64, **kw)
    p = tr.init_params(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
    last, cache = tr.prefill(p, t, cfg, max_len=20)
    full = tr.forward(p, t, cfg)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)
    # three greedy decode steps must match teacher forcing
    lengths = jnp.full((2,), 12, jnp.int32)
    toks = t
    for _ in range(3):
        nxt = jnp.argmax(last, -1)[:, None]
        last, cache = tr.decode_step(p, cache, nxt, lengths, cfg)
        toks = jnp.concatenate([toks, nxt], axis=1)
        lengths = lengths + 1
        ref = tr.forward(p, toks, cfg)[:, -1]
        np.testing.assert_allclose(np.asarray(last), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)


def test_moe_capacity_drops_gracefully():
    moe = tr.MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=16,
                       capacity_factor=0.5)  # deliberately tight
    cfg = tiny_cfg(moe=moe)
    p = tr.init_params(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    out = tr.forward(p, t, cfg)
    assert bool(jnp.isfinite(out).all())


def test_moe_matches_dense_expert_sum():
    """With top_k == n_experts and ample capacity, routed MoE must equal the
    weighted sum of every expert's FFN (dense verification of dispatch)."""
    moe = tr.MoEConfig(n_experts=4, top_k=4, n_shared=0, d_expert=16,
                       capacity_factor=4.0)
    cfg = tiny_cfg(moe=moe, n_layers=1)
    p = tr.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 32), jnp.float32)
    lp = jax.tree.map(lambda a: a[0], p["groups"]["global"])
    got = tr._moe_dispatch_local(x, lp, cfg, moe.n_experts, 0, None)
    # dense reference
    logits = x @ lp["router"]
    w = jax.nn.softmax(logits, -1)
    ref = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ lp["we1"][e]) * (x @ lp["we3"][e])
        ref += w[:, e:e + 1] * (h @ lp["we2"][e])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_padded_vocab_invariance():
    cfg = tiny_cfg(vocab=50)     # pads to 512
    assert cfg.padded_vocab == 512
    p = tr.init_params(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 50)
    loss = tr.loss_fn(p, {"tokens": t, "labels": t}, cfg)
    assert bool(jnp.isfinite(loss))
